//! The compact binary trace format, version 1.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset 0   magic       b"LISATRCE"                       (8 bytes)
//!        8   version     u32 = 1                           (4 bytes)
//!       12   core_count  u32                               (4 bytes)
//!       16   name_len    u32                               (4 bytes)
//!       20   name        UTF-8 workload name     (name_len bytes)
//!        .   directory   core_count x StreamDesc      (24 bytes each)
//!        .   streams     per-core varint-encoded op streams
//! ```
//!
//! Each `StreamDesc` is `{ op_count: u64, offset: u64, len: u64 }`:
//! the op count, absolute file offset and byte length of that core's
//! stream. The directory is fixed-width so the header can be written
//! before the streams and patched afterwards, and so a reader can
//! seek straight to any core.
//!
//! Ops are encoded as a tag byte followed by LEB128 varints. All
//! addresses (`addr`, `src`/`dst`, `va`) are zigzag-encoded deltas
//! against the previous address in the same stream — trace addresses
//! have strong spatial locality, so deltas keep most addresses to 1-3
//! bytes. Varints longer than 10 bytes (or with payload bits beyond
//! the 64th) are rejected as over-long rather than silently wrapped.

use anyhow::{anyhow, bail, Context, Result};

use crate::cpu::trace::{BulkOp, TraceOp};

pub const MAGIC: [u8; 8] = *b"LISATRCE";
pub const VERSION: u32 = 1;
/// Bytes before the (variable-length) name: magic + version +
/// core_count + name_len.
pub const FIXED_HEADER_BYTES: u64 = 20;
pub const STREAM_DESC_BYTES: u64 = 24;
/// Sanity bounds: a header claiming more is corrupt, not big.
pub const MAX_CORES: u32 = 4096;
pub const MAX_NAME_BYTES: u32 = 4096;

/// Op tag bytes.
pub const TAG_MEM: u8 = 0;
pub const TAG_COPY: u8 = 1;
pub const TAG_BULK_MEMCPY: u8 = 2;
pub const TAG_BULK_ZERO: u8 = 3;
pub const TAG_BULK_FORK: u8 = 4;
pub const TAG_BULK_TOUCH: u8 = 5;
pub const TAG_BULK_CHECKPOINT: u8 = 6;
pub const TAG_BULK_PROMOTE: u8 = 7;

/// One core stream's directory entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamDesc {
    pub op_count: u64,
    /// Absolute file offset of the stream's first byte.
    pub offset: u64,
    /// Stream length in bytes.
    pub len: u64,
}

/// The decoded file header.
#[derive(Debug, Clone)]
pub struct TraceHeader {
    pub name: String,
    pub streams: Vec<StreamDesc>,
}

impl TraceHeader {
    /// Total header bytes (fixed part + name + directory) for a
    /// header with this name and core count.
    pub fn byte_len(name: &str, cores: usize) -> u64 {
        FIXED_HEADER_BYTES + name.len() as u64 + cores as u64 * STREAM_DESC_BYTES
    }

    /// Serialize the full header (directory included).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            Self::byte_len(&self.name, self.streams.len()) as usize,
        );
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.streams.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.name.len() as u32).to_le_bytes());
        out.extend_from_slice(self.name.as_bytes());
        for s in &self.streams {
            out.extend_from_slice(&s.op_count.to_le_bytes());
            out.extend_from_slice(&s.offset.to_le_bytes());
            out.extend_from_slice(&s.len.to_le_bytes());
        }
        out
    }

    /// Parse and validate the fixed 20-byte prefix; returns
    /// `(core_count, name_len)`.
    pub fn decode_fixed(prefix: &[u8; 20]) -> Result<(u32, u32)> {
        if prefix[0..8] != MAGIC {
            bail!(
                "bad magic {:02x?} (expected {:02x?}: not a LISA trace file)",
                &prefix[0..8],
                MAGIC
            );
        }
        let version = u32::from_le_bytes(prefix[8..12].try_into().unwrap());
        if version != VERSION {
            bail!("unsupported trace format version {version} (this build reads {VERSION})");
        }
        let core_count = u32::from_le_bytes(prefix[12..16].try_into().unwrap());
        if core_count == 0 || core_count > MAX_CORES {
            bail!("implausible core count {core_count} (limit {MAX_CORES})");
        }
        let name_len = u32::from_le_bytes(prefix[16..20].try_into().unwrap());
        if name_len > MAX_NAME_BYTES {
            bail!("implausible workload name length {name_len} (limit {MAX_NAME_BYTES})");
        }
        Ok((core_count, name_len))
    }

    /// Parse the variable part (name + directory) given the fixed
    /// prefix results, validating every stream against `file_len`.
    pub fn decode_tail(
        core_count: u32,
        name_len: u32,
        tail: &[u8],
        file_len: u64,
    ) -> Result<TraceHeader> {
        let need = name_len as usize + (core_count as u64 * STREAM_DESC_BYTES) as usize;
        if tail.len() != need {
            bail!("truncated header: {} of {need} bytes", tail.len());
        }
        let name = std::str::from_utf8(&tail[..name_len as usize])
            .context("workload name is not UTF-8")?
            .to_string();
        let header_end = FIXED_HEADER_BYTES + need as u64;
        let mut streams = Vec::with_capacity(core_count as usize);
        let mut dir = &tail[name_len as usize..];
        for core in 0..core_count {
            let op_count = u64::from_le_bytes(dir[0..8].try_into().unwrap());
            let offset = u64::from_le_bytes(dir[8..16].try_into().unwrap());
            let len = u64::from_le_bytes(dir[16..24].try_into().unwrap());
            dir = &dir[24..];
            if offset < header_end {
                bail!("core {core} stream offset {offset} overlaps the header");
            }
            let end = offset
                .checked_add(len)
                .ok_or_else(|| anyhow!("core {core} stream offset+len overflows"))?;
            if end > file_len {
                bail!(
                    "core {core} stream [{offset}, {end}) runs past end of file ({file_len} bytes)"
                );
            }
            streams.push(StreamDesc { op_count, offset, len });
        }
        Ok(TraceHeader { name, streams })
    }
}

/// A pull source of bytes for the decoder (a slice, or the reader's
/// chunked file buffer).
pub(crate) trait ByteSource {
    fn next_byte(&mut self) -> Result<u8>;
}

pub(crate) struct SliceSource<'a> {
    pub buf: &'a [u8],
    pub pos: usize,
}

impl ByteSource for SliceSource<'_> {
    fn next_byte(&mut self) -> Result<u8> {
        let b = self
            .buf
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of data at byte {}", self.pos))?;
        self.pos += 1;
        Ok(b)
    }
}

/// Append a LEB128 varint (canonical: minimal length).
pub fn push_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(b);
            break;
        }
        buf.push(b | 0x80);
    }
}

/// Read a LEB128 varint; over-long encodings (an 11th byte, or
/// payload bits beyond the 64th) are an error, never a wrap.
pub(crate) fn read_varint(src: &mut dyn ByteSource) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    for i in 0..10 {
        let b = src.next_byte().context("inside a varint")?;
        let payload = (b & 0x7f) as u64;
        if i == 9 && payload > 1 {
            bail!("over-long varint (10th byte 0x{b:02x} overflows u64)");
        }
        v |= payload << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
    bail!("over-long varint (no terminator within 10 bytes)")
}

pub fn zigzag(d: i64) -> u64 {
    ((d << 1) ^ (d >> 63)) as u64
}

pub fn unzigzag(z: u64) -> i64 {
    ((z >> 1) as i64) ^ -((z & 1) as i64)
}

/// Append an address as a zigzag delta against (and updating) `prev`.
fn push_addr(buf: &mut Vec<u8>, addr: u64, prev: &mut u64) {
    push_varint(buf, zigzag(addr.wrapping_sub(*prev) as i64));
    *prev = addr;
}

fn read_addr(src: &mut dyn ByteSource, prev: &mut u64) -> Result<u64> {
    let d = unzigzag(read_varint(src)?);
    let addr = prev.wrapping_add(d as u64);
    *prev = addr;
    Ok(addr)
}

fn flags(is_write: bool, dependent: bool) -> u8 {
    (is_write as u8) | ((dependent as u8) << 1)
}

fn read_flags(src: &mut dyn ByteSource) -> Result<(bool, bool)> {
    let f = src.next_byte().context("inside an access-flags byte")?;
    if f > 3 {
        bail!("invalid access-flags byte 0x{f:02x}");
    }
    Ok((f & 1 != 0, f & 2 != 0))
}

fn read_u32(src: &mut dyn ByteSource, what: &str) -> Result<u32> {
    let v = read_varint(src)?;
    u32::try_from(v).map_err(|_| anyhow!("{what} {v} exceeds u32"))
}

/// Encode one op into `buf`, threading the stream's previous-address
/// state.
pub fn encode_op(buf: &mut Vec<u8>, op: &TraceOp, prev: &mut u64) {
    match *op {
        TraceOp::Mem { nonmem, addr, is_write, dependent } => {
            buf.push(TAG_MEM);
            push_varint(buf, nonmem as u64);
            buf.push(flags(is_write, dependent));
            push_addr(buf, addr, prev);
        }
        TraceOp::Copy { nonmem, src, dst, rows } => {
            buf.push(TAG_COPY);
            push_varint(buf, nonmem as u64);
            push_varint(buf, rows as u64);
            push_addr(buf, src, prev);
            push_addr(buf, dst, prev);
        }
        TraceOp::Bulk { nonmem, op } => match op {
            BulkOp::Memcpy { src_va, dst_va, pages } => {
                buf.push(TAG_BULK_MEMCPY);
                push_varint(buf, nonmem as u64);
                push_varint(buf, pages as u64);
                push_addr(buf, src_va, prev);
                push_addr(buf, dst_va, prev);
            }
            BulkOp::Zero { va, pages } => {
                buf.push(TAG_BULK_ZERO);
                push_varint(buf, nonmem as u64);
                push_varint(buf, pages as u64);
                push_addr(buf, va, prev);
            }
            BulkOp::Fork => {
                buf.push(TAG_BULK_FORK);
                push_varint(buf, nonmem as u64);
            }
            BulkOp::Touch { va, is_write, dependent } => {
                buf.push(TAG_BULK_TOUCH);
                push_varint(buf, nonmem as u64);
                buf.push(flags(is_write, dependent));
                push_addr(buf, va, prev);
            }
            BulkOp::Checkpoint => {
                buf.push(TAG_BULK_CHECKPOINT);
                push_varint(buf, nonmem as u64);
            }
            BulkOp::Promote { va } => {
                buf.push(TAG_BULK_PROMOTE);
                push_varint(buf, nonmem as u64);
                push_addr(buf, va, prev);
            }
        },
    }
}

/// Decode one op, threading the stream's previous-address state.
pub(crate) fn decode_op(src: &mut dyn ByteSource, prev: &mut u64) -> Result<TraceOp> {
    let tag = src.next_byte().context("at an op tag")?;
    let op = match tag {
        TAG_MEM => {
            let nonmem = read_u32(src, "nonmem")?;
            let (is_write, dependent) = read_flags(src)?;
            let addr = read_addr(src, prev)?;
            TraceOp::Mem { nonmem, addr, is_write, dependent }
        }
        TAG_COPY => {
            let nonmem = read_u32(src, "nonmem")?;
            let rows = read_u32(src, "rows")?;
            let src_a = read_addr(src, prev)?;
            let dst_a = read_addr(src, prev)?;
            TraceOp::Copy { nonmem, src: src_a, dst: dst_a, rows }
        }
        TAG_BULK_MEMCPY => {
            let nonmem = read_u32(src, "nonmem")?;
            let pages = read_u32(src, "pages")?;
            let src_va = read_addr(src, prev)?;
            let dst_va = read_addr(src, prev)?;
            TraceOp::Bulk { nonmem, op: BulkOp::Memcpy { src_va, dst_va, pages } }
        }
        TAG_BULK_ZERO => {
            let nonmem = read_u32(src, "nonmem")?;
            let pages = read_u32(src, "pages")?;
            let va = read_addr(src, prev)?;
            TraceOp::Bulk { nonmem, op: BulkOp::Zero { va, pages } }
        }
        TAG_BULK_FORK => {
            let nonmem = read_u32(src, "nonmem")?;
            TraceOp::Bulk { nonmem, op: BulkOp::Fork }
        }
        TAG_BULK_TOUCH => {
            let nonmem = read_u32(src, "nonmem")?;
            let (is_write, dependent) = read_flags(src)?;
            let va = read_addr(src, prev)?;
            TraceOp::Bulk { nonmem, op: BulkOp::Touch { va, is_write, dependent } }
        }
        TAG_BULK_CHECKPOINT => {
            let nonmem = read_u32(src, "nonmem")?;
            TraceOp::Bulk { nonmem, op: BulkOp::Checkpoint }
        }
        TAG_BULK_PROMOTE => {
            let nonmem = read_u32(src, "nonmem")?;
            let va = read_addr(src, prev)?;
            TraceOp::Bulk { nonmem, op: BulkOp::Promote { va } }
        }
        other => bail!("unknown op tag 0x{other:02x}"),
    };
    Ok(op)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_one(v: u64) {
        let mut buf = Vec::new();
        push_varint(&mut buf, v);
        assert!(buf.len() <= 10);
        let mut s = SliceSource { buf: &buf, pos: 0 };
        assert_eq!(read_varint(&mut s).unwrap(), v);
        assert_eq!(s.pos, buf.len(), "varint for {v} left trailing bytes");
    }

    #[test]
    fn varints_round_trip() {
        for v in [0u64, 1, 127, 128, 300, 1 << 20, u32::MAX as u64, u64::MAX] {
            roundtrip_one(v);
        }
    }

    #[test]
    fn over_long_varints_are_rejected() {
        // 11 continuation bytes: no terminator within the limit.
        let buf = [0x80u8; 11];
        let mut s = SliceSource { buf: &buf, pos: 0 };
        let err = read_varint(&mut s).unwrap_err().to_string();
        assert!(err.contains("over-long"), "{err}");
        // 10 bytes but the last one carries payload beyond bit 63.
        let mut buf = vec![0x80u8; 9];
        buf.push(0x02);
        let mut s = SliceSource { buf: &buf, pos: 0 };
        let err = read_varint(&mut s).unwrap_err().to_string();
        assert!(err.contains("over-long"), "{err}");
        // u64::MAX itself is fine (10th byte is 0x01).
        roundtrip_one(u64::MAX);
    }

    #[test]
    fn zigzag_round_trips_extremes() {
        for d in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(d)), d);
        }
        // Small magnitudes get small codes (the point of zigzag).
        assert!(zigzag(-1) < 8 && zigzag(1) < 8);
    }

    #[test]
    fn every_op_kind_round_trips() {
        let ops = vec![
            TraceOp::Mem { nonmem: 3, addr: 0xdead_beef, is_write: true, dependent: false },
            TraceOp::Mem { nonmem: 0, addr: 0xdead_bf4f, is_write: false, dependent: true },
            TraceOp::Copy { nonmem: 10, src: 8192, dst: 1 << 30, rows: 4 },
            TraceOp::Bulk {
                nonmem: 20,
                op: BulkOp::Memcpy { src_va: 0, dst_va: 1 << 40, pages: 16 },
            },
            TraceOp::Bulk { nonmem: 20, op: BulkOp::Zero { va: 64, pages: 64 } },
            TraceOp::Bulk { nonmem: 60, op: BulkOp::Fork },
            TraceOp::Bulk {
                nonmem: 4,
                op: BulkOp::Touch { va: 12288, is_write: true, dependent: true },
            },
            TraceOp::Bulk { nonmem: 20, op: BulkOp::Checkpoint },
            TraceOp::Bulk { nonmem: 20, op: BulkOp::Promote { va: u64::MAX - 63 } },
        ];
        let mut buf = Vec::new();
        let mut prev = 0u64;
        for op in &ops {
            encode_op(&mut buf, op, &mut prev);
        }
        let mut s = SliceSource { buf: &buf, pos: 0 };
        let mut prev = 0u64;
        let back: Vec<TraceOp> =
            (0..ops.len()).map(|_| decode_op(&mut s, &mut prev).unwrap()).collect();
        assert_eq!(back, ops);
        assert_eq!(s.pos, buf.len(), "decoder left trailing bytes");
    }

    #[test]
    fn nearby_addresses_encode_compactly() {
        // A 64-byte-stride stream: after the first op, each Mem op is
        // tag + nonmem + flags + 1-2 byte delta.
        let mut buf = Vec::new();
        let mut prev = 0u64;
        for i in 0..100u64 {
            encode_op(
                &mut buf,
                &TraceOp::Mem {
                    nonmem: 4,
                    addr: (40 << 30) + i * 64,
                    is_write: false,
                    dependent: false,
                },
                &mut prev,
            );
        }
        assert!(buf.len() < 100 * 6, "{} bytes for 100 strided ops", buf.len());
    }

    #[test]
    fn header_encodes_and_decodes() {
        let h = TraceHeader {
            name: "gc-chase".into(),
            streams: vec![
                StreamDesc { op_count: 10, offset: 76, len: 40 },
                StreamDesc { op_count: 5, offset: 116, len: 21 },
            ],
        };
        let bytes = h.encode();
        assert_eq!(bytes.len() as u64, TraceHeader::byte_len("gc-chase", 2));
        let fixed: [u8; 20] = bytes[..20].try_into().unwrap();
        let (cores, name_len) = TraceHeader::decode_fixed(&fixed).unwrap();
        assert_eq!((cores, name_len), (2, 8));
        let back = TraceHeader::decode_tail(cores, name_len, &bytes[20..], 137).unwrap();
        assert_eq!(back.name, h.name);
        assert_eq!(back.streams, h.streams);
    }

    #[test]
    fn corrupt_headers_are_contextual_errors() {
        let h = TraceHeader {
            name: "x".into(),
            streams: vec![StreamDesc { op_count: 1, offset: 45, len: 5 }],
        };
        let good = h.encode();
        let file_len = 50u64;

        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        let fixed: [u8; 20] = bad[..20].try_into().unwrap();
        let err = TraceHeader::decode_fixed(&fixed).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");

        // Wrong version.
        let mut bad = good.clone();
        bad[8] = 99;
        let fixed: [u8; 20] = bad[..20].try_into().unwrap();
        let err = TraceHeader::decode_fixed(&fixed).unwrap_err().to_string();
        assert!(err.contains("version 99"), "{err}");

        // Stream running past EOF.
        let fixed: [u8; 20] = good[..20].try_into().unwrap();
        let (c, n) = TraceHeader::decode_fixed(&fixed).unwrap();
        let err = TraceHeader::decode_tail(c, n, &good[20..], 47).unwrap_err().to_string();
        assert!(err.contains("past end of file"), "{err}");

        // Stream overlapping the header.
        let mut bad = good.clone();
        // offset field of stream 0 lives at 20 + name_len(1) + 8.
        bad[29..37].copy_from_slice(&3u64.to_le_bytes());
        let err = TraceHeader::decode_tail(c, n, &bad[20..], file_len)
            .unwrap_err()
            .to_string();
        assert!(err.contains("overlaps the header"), "{err}");
    }
}
