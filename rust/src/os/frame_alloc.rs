//! Subarray-aware physical frame allocator.
//!
//! A *frame* is one OS-visible DRAM row (the page size of this OS
//! layer is one 8 KB row, the granularity every in-DRAM copy mechanism
//! moves). Frames are grouped by *subarray group* — the (channel,
//! rank, bank, subarray) tuple — because that is the unit the copy
//! mechanisms care about: pairs in the same subarray copy with
//! RowClone intra-SA, pairs in the same bank with LISA-RISC, and
//! anything further must fall back to RowClone-PSM or memcpy over the
//! channel. Placement is therefore a first-class performance knob
//! (`PlacementPolicy`), evaluated by experiment E9.
//!
//! The lowest visible subarray of every bank is held back as the
//! *promotion zone*: only `alloc_zone` (hot-page migration toward the
//! VILLA fast subarray at the bank's bottom) places frames there.

use crate::config::{DramConfig, PlacementPolicy};
use crate::dram::geometry::Address;
use crate::util::rng::Pcg32;

/// Subarray levels per bank reserved for hot-page promotion.
pub const ZONE_LEVELS: usize = 1;

/// The allocator. Frame ids are global visible-row indices:
/// `frame = bank_group * visible_rows + visible_row`, with bank groups
/// ordered `(channel, rank, bank)` — the same convention VILLA uses.
#[derive(Debug, Clone)]
pub struct FrameAlloc {
    /// Free stacks per subarray group (push/pop at the tail; the
    /// initial fill is descending so pop yields ascending frames).
    free: Vec<Vec<u32>>,
    /// Per-frame reference counts (CoW sharing).
    refcnt: Vec<u16>,
    policy: PlacementPolicy,
    rng: Pcg32,
    spread_cursor: usize,
    villa_rr: usize,
    groups_per_bank: usize,
    banks_total: usize,
    visible_rows: usize,
    rows_per_sa: usize,
    /// Rows per bank reserved (below the visible space) for VILLA.
    reserved: usize,
    ranks: usize,
    banks: usize,
}

impl FrameAlloc {
    pub fn new(dram: &DramConfig, reserved: usize, policy: PlacementPolicy, seed: u64) -> Self {
        let visible_rows = dram.rows_per_bank() - reserved;
        let rows_per_sa = dram.rows_per_subarray;
        assert_eq!(
            visible_rows % rows_per_sa,
            0,
            "reserved rows must be whole subarrays"
        );
        let groups_per_bank = visible_rows / rows_per_sa;
        let banks_total = dram.channels * dram.ranks * dram.banks;
        let n_frames = banks_total * visible_rows;
        let mut free = vec![Vec::new(); banks_total * groups_per_bank];
        // Descending fill so pop() hands out the lowest frame first.
        for f in (0..n_frames as u32).rev() {
            let g = Self::group_of_raw(f, visible_rows, rows_per_sa, groups_per_bank);
            free[g].push(f);
        }
        Self {
            free,
            refcnt: vec![0; n_frames],
            policy,
            rng: Pcg32::new(seed, 0x05_A110C),
            spread_cursor: 0,
            villa_rr: 0,
            groups_per_bank,
            banks_total,
            visible_rows,
            rows_per_sa,
            reserved,
            ranks: dram.ranks,
            banks: dram.banks,
        }
    }

    fn group_of_raw(
        frame: u32,
        visible_rows: usize,
        rows_per_sa: usize,
        groups_per_bank: usize,
    ) -> usize {
        let gb = frame as usize / visible_rows;
        let level = (frame as usize % visible_rows) / rows_per_sa;
        gb * groups_per_bank + level
    }

    /// Subarray group of a frame.
    pub fn group_of(&self, frame: u32) -> usize {
        Self::group_of_raw(frame, self.visible_rows, self.rows_per_sa, self.groups_per_bank)
    }

    /// Bank group (channel-rank-bank index) of a frame.
    pub fn bank_of(&self, frame: u32) -> usize {
        frame as usize / self.visible_rows
    }

    /// Visible subarray level (0 = promotion zone) of a frame.
    pub fn level_of(&self, frame: u32) -> usize {
        (frame as usize % self.visible_rows) / self.rows_per_sa
    }

    /// DRAM coordinates of a frame's row.
    pub fn addr_of(&self, frame: u32) -> Address {
        let gb = self.bank_of(frame);
        let vrow = frame as usize % self.visible_rows;
        let channel = gb / (self.ranks * self.banks);
        let rem = gb % (self.ranks * self.banks);
        Address {
            channel,
            rank: rem / self.banks,
            bank: rem % self.banks,
            row: self.reserved + vrow,
            col: 0,
        }
    }

    pub fn free_frames(&self) -> usize {
        self.free.iter().map(|g| g.len()).sum()
    }

    /// Is this group open to general allocation (not the promotion
    /// zone)?
    fn general(&self, group: usize) -> bool {
        group % self.groups_per_bank >= ZONE_LEVELS.min(self.groups_per_bank - 1)
    }

    fn take(&mut self, group: usize) -> Option<u32> {
        let f = self.free[group].pop()?;
        self.refcnt[f as usize] = 1;
        Some(f)
    }

    /// Allocate a frame under the configured placement policy.
    pub fn alloc(&mut self) -> Option<u32> {
        let n = self.free.len();
        match self.policy {
            PlacementPolicy::Random => {
                let start = self.rng.below(n as u64) as usize;
                (0..n)
                    .map(|k| (start + k) % n)
                    .find(|&g| self.general(g) && !self.free[g].is_empty())
                    .and_then(|g| self.take(g))
            }
            PlacementPolicy::SubarrayPacked => (0..n)
                .find(|&g| self.general(g) && !self.free[g].is_empty())
                .and_then(|g| self.take(g)),
            PlacementPolicy::SubarraySpread => {
                // Rotation order iterates BANKS fastest (r -> bank
                // r % banks, level r / banks), so consecutive
                // allocations land in different banks — the deliberate
                // anti-co-location endpoint of the placement axis.
                for k in 1..=n {
                    let r = (self.spread_cursor + k) % n;
                    let bank = r % self.banks_total;
                    let level = (r / self.banks_total) % self.groups_per_bank;
                    let g = bank * self.groups_per_bank + level;
                    if self.general(g) && !self.free[g].is_empty() {
                        self.spread_cursor = r;
                        return self.take(g);
                    }
                }
                None
            }
            PlacementPolicy::VillaAware => {
                for level in ZONE_LEVELS.min(self.groups_per_bank - 1)..self.groups_per_bank {
                    for k in 0..self.banks_total {
                        let b = (self.villa_rr + k) % self.banks_total;
                        let g = b * self.groups_per_bank + level;
                        if !self.free[g].is_empty() {
                            self.villa_rr = (b + 1) % self.banks_total;
                            return self.take(g);
                        }
                    }
                }
                None
            }
        }
    }

    /// Allocate a copy destination near `src` (the placement knob that
    /// decides the RISC hit rate): co-locating policies try the source
    /// bank first, nearest subarray level outward, then other banks of
    /// the same rank; spreading policies deliberately ignore `src`.
    pub fn alloc_near(&mut self, src: u32) -> Option<u32> {
        match self.policy {
            PlacementPolicy::Random | PlacementPolicy::SubarraySpread => self.alloc(),
            PlacementPolicy::SubarrayPacked | PlacementPolicy::VillaAware => {
                let gb = self.bank_of(src);
                let src_level = self.level_of(src);
                let floor = ZONE_LEVELS.min(self.groups_per_bank - 1);
                // Same bank, nearest level first (lower level wins ties:
                // shorter RBM hops toward the fast subarray).
                let mut levels: Vec<usize> = (floor..self.groups_per_bank).collect();
                levels.sort_by_key(|&l| (l.abs_diff(src_level), l));
                for l in levels {
                    let g = gb * self.groups_per_bank + l;
                    if !self.free[g].is_empty() {
                        return self.take(g);
                    }
                }
                // Other banks of the same channel+rank, packed order.
                let bank_base = gb - gb % self.banks;
                for b in bank_base..bank_base + self.banks {
                    if b == gb {
                        continue;
                    }
                    for l in floor..self.groups_per_bank {
                        let g = b * self.groups_per_bank + l;
                        if !self.free[g].is_empty() {
                            return self.take(g);
                        }
                    }
                }
                self.alloc()
            }
        }
    }

    /// Allocate in `frame`'s bank's promotion zone (hot-page
    /// migration); `None` when the zone is full.
    pub fn alloc_zone(&mut self, frame: u32) -> Option<u32> {
        let gb = self.bank_of(frame);
        for level in 0..ZONE_LEVELS.min(self.groups_per_bank) {
            let g = gb * self.groups_per_bank + level;
            if !self.free[g].is_empty() {
                return self.take(g);
            }
        }
        None
    }

    /// Allocate from the *top* group of `bank` (used for the per-bank
    /// zero rows, keeping them clear of both the promotion zone and
    /// the packed allocation front).
    pub fn alloc_top(&mut self, bank_group: usize) -> Option<u32> {
        for level in (0..self.groups_per_bank).rev() {
            let g = bank_group * self.groups_per_bank + level;
            if !self.free[g].is_empty() {
                return self.take(g);
            }
        }
        None
    }

    /// Add a reference (fork sharing).
    pub fn retain(&mut self, frame: u32) {
        self.refcnt[frame as usize] += 1;
    }

    /// Drop a reference; the frame returns to its free stack when the
    /// count reaches zero. Returns true if the frame was freed.
    pub fn release(&mut self, frame: u32) -> bool {
        let rc = &mut self.refcnt[frame as usize];
        debug_assert!(*rc > 0, "release of free frame {frame}");
        *rc -= 1;
        if *rc == 0 {
            let g = self.group_of(frame);
            self.free[g].push(frame);
            true
        } else {
            false
        }
    }

    pub fn refcount(&self, frame: u32) -> u16 {
        self.refcnt[frame as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alloc(policy: PlacementPolicy) -> FrameAlloc {
        FrameAlloc::new(&DramConfig::default(), 0, policy, 7)
    }

    #[test]
    fn geometry_round_trip() {
        let fa = alloc(PlacementPolicy::SubarrayPacked);
        // Default geometry: 8 banks * 16 SAs * 512 rows.
        assert_eq!(fa.free_frames(), 8 * 16 * 512);
        let f = 3 * 8192 + 2 * 512 + 17; // bank 3, subarray 2, row 17
        let a = fa.addr_of(f);
        assert_eq!((a.channel, a.rank, a.bank), (0, 0, 3));
        assert_eq!(a.row, 2 * 512 + 17);
        assert_eq!(fa.level_of(f), 2);
        assert_eq!(fa.bank_of(f), 3);
    }

    #[test]
    fn packed_fills_one_subarray_before_the_next() {
        let mut fa = alloc(PlacementPolicy::SubarrayPacked);
        let frames: Vec<u32> = (0..600).map(|_| fa.alloc().unwrap()).collect();
        // General allocation skips the promotion zone (level 0).
        assert!(frames.iter().all(|&f| fa.level_of(f) >= ZONE_LEVELS));
        // First 512 allocations land in one subarray group, same bank.
        let g0 = fa.group_of(frames[0]);
        assert!(frames[..512].iter().all(|&f| fa.group_of(f) == g0));
        assert_ne!(fa.group_of(frames[512]), g0);
        assert!(frames[..600].iter().all(|&f| fa.bank_of(f) == 0));
    }

    #[test]
    fn spread_round_robins_banks() {
        let mut fa = alloc(PlacementPolicy::SubarraySpread);
        let a = fa.alloc().unwrap();
        let b = fa.alloc().unwrap();
        let c = fa.alloc().unwrap();
        assert_ne!(fa.group_of(a), fa.group_of(b));
        assert_ne!(fa.group_of(b), fa.group_of(c));
        // Consecutive allocations land in different banks.
        assert_ne!(fa.bank_of(a), fa.bank_of(b));
        assert_ne!(fa.bank_of(b), fa.bank_of(c));
    }

    #[test]
    fn villa_aware_packs_low_levels_across_banks() {
        let mut fa = alloc(PlacementPolicy::VillaAware);
        let frames: Vec<u32> = (0..16).map(|_| fa.alloc().unwrap()).collect();
        // First pass: level 1 (lowest general) of 8 banks round-robin.
        assert!(frames[..8].iter().all(|&f| fa.level_of(f) == 1));
        let banks: Vec<usize> = frames[..8].iter().map(|&f| fa.bank_of(f)).collect();
        assert_eq!(banks, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn alloc_near_colocates_under_packed_but_not_random() {
        let mut packed = alloc(PlacementPolicy::SubarrayPacked);
        let src = packed.alloc().unwrap();
        let near = packed.alloc_near(src).unwrap();
        assert_eq!(packed.bank_of(src), packed.bank_of(near));

        let mut rnd = alloc(PlacementPolicy::Random);
        let src = rnd.alloc().unwrap();
        let same_bank = (0..64)
            .filter(|_| {
                let f = rnd.alloc_near(src).unwrap();
                rnd.bank_of(f) == rnd.bank_of(src)
            })
            .count();
        assert!(same_bank < 32, "random placement co-located {same_bank}/64");
    }

    #[test]
    fn refcounts_gate_freeing() {
        let mut fa = alloc(PlacementPolicy::SubarrayPacked);
        let before = fa.free_frames();
        let f = fa.alloc().unwrap();
        fa.retain(f);
        assert_eq!(fa.refcount(f), 2);
        assert!(!fa.release(f));
        assert_eq!(fa.free_frames(), before - 1);
        assert!(fa.release(f));
        assert_eq!(fa.free_frames(), before);
        // LIFO reuse: the freed frame comes back first.
        assert_eq!(fa.alloc().unwrap(), f);
    }

    #[test]
    fn zone_allocation_stays_in_bank_and_zone() {
        let mut fa = alloc(PlacementPolicy::SubarrayPacked);
        let src = fa.alloc().unwrap(); // bank 0, level >= 1
        let z = fa.alloc_zone(src).unwrap();
        assert_eq!(fa.bank_of(z), fa.bank_of(src));
        assert_eq!(fa.level_of(z), 0);
        // The zone holds one subarray (512 frames); drain it.
        for _ in 1..512 {
            assert!(fa.alloc_zone(src).is_some());
        }
        assert!(fa.alloc_zone(src).is_none(), "zone should be exhausted");
    }

    #[test]
    fn reserved_rows_shift_the_visible_space() {
        // One reserved subarray (VILLA): rows start at 512.
        let fa = FrameAlloc::new(
            &DramConfig::default(),
            512,
            PlacementPolicy::SubarrayPacked,
            1,
        );
        assert_eq!(fa.free_frames(), 8 * 15 * 512);
        assert_eq!(fa.addr_of(0).row, 512);
    }
}
