//! Clean fixture: exercises the lexer's corner cases — raw strings
//! holding braces and comment markers, nested block comments, char
//! literals that look like braces, multi-line strings — and a fully
//! conventional config/serializer/probe surface. Zero diagnostics
//! expected. Not compiled — lexed by lint tests only.

/* a block comment /* nested */ still inside the outer one */

#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimConfig {
    pub seed: u64,
}

impl SimConfig {
    pub fn to_toml(&self) -> String {
        // The brace and slashes below live in literals and must not
        // confuse the scanner.
        let _tricky = r#"not a { scope " and // not a comment"#;
        let _ch = '{';
        format!("seed = {}\n", self.seed)
    }

    pub fn apply(&mut self, doc: &str) {
        if let Some(v) = doc.strip_prefix("seed = ") {
            self.seed = v.trim().parse().unwrap_or(0);
        }
    }

    pub fn from_toml(text: &str) -> Self {
        let mut c = Self::default();
        c.apply(text);
        c
    }

    pub fn content_hash(&self) -> u64 {
        self.to_toml().len() as u64
    }
}

pub struct Stats {
    pub reads: u64,
    obs: Option<u32>,
}

impl Stats {
    fn observing(&self) -> bool {
        self.obs.is_some()
    }

    pub fn to_json(&self) -> String {
        format!("{{\"reads\":{}}}", self.reads)
    }

    pub fn from_json(text: &str) -> Stats {
        let reads = text.contains("reads") as u64;
        Stats { reads, obs: None }
    }

    pub fn tick(&mut self) {
        if self.observing() {
            self.observe(1);
        }
    }

    fn observe(&mut self, ev: u32) {
        if let Some(o) = self.obs.as_mut() {
            *o = ev;
        }
    }
}
