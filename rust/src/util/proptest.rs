//! Tiny property-testing harness (the offline registry has no
//! `proptest`). Runs a property over many seeded random cases; on
//! failure it re-runs a bounded "shrink" pass that retries the property
//! with simpler draws (smaller integers) from the failing seed
//! neighborhood, and always reports the failing seed so the case can be
//! replayed deterministically.
//!
//! ```
//! use lisa::util::proptest::{check, Gen};
//! check("addition commutes", 256, |g| {
//!     let a = g.u64(1000);
//!     let b = g.u64(1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Pcg32;

/// Per-case generator handed to properties.
pub struct Gen {
    rng: Pcg32,
    /// Shrink factor in (0, 1]: draws scale down as it decreases.
    scale: f64,
}

impl Gen {
    fn new(seed: u64, case: u64, scale: f64) -> Self {
        Self {
            rng: Pcg32::new(seed, case),
            scale,
        }
    }

    /// Uniform u64 in [0, bound), scaled down during shrinking.
    pub fn u64(&mut self, bound: u64) -> u64 {
        let eff = ((bound as f64 * self.scale).ceil() as u64).max(1);
        self.rng.below(eff.min(bound))
    }

    /// Uniform usize in [0, bound).
    pub fn usize(&mut self, bound: usize) -> usize {
        self.u64(bound as u64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.u64(hi - lo + 1)
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.usize(xs.len());
        &xs[i]
    }

    /// A vector of `len` draws from `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }
}

/// Environment-overridable base seed so CI can replay failures:
/// `LISA_PROPTEST_SEED=12345 cargo test`.
fn base_seed() -> u64 {
    std::env::var("LISA_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FF_EE00_5EED_1234)
}

/// Run `prop` over `cases` random cases. Panics (with the failing seed
/// and case index) if any case fails; attempts shrunk re-runs first so
/// the reported failure is as small as the harness can find.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let seed = base_seed();
    for case in 0..cases {
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, case, 1.0);
            prop(&mut g);
        });
        if result.is_err() {
            // Shrink: retry with progressively smaller draw scales and
            // report the smallest still-failing configuration.
            let mut failing_scale = 1.0;
            for &scale in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                let shrunk = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, case, scale);
                    prop(&mut g);
                });
                if shrunk.is_err() {
                    failing_scale = scale;
                }
            }
            panic!(
                "property '{name}' failed: seed={seed:#x} case={case} \
                 scale={failing_scale} (replay with LISA_PROPTEST_SEED={seed})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u64 below bound", 200, |g| {
            let b = g.range(1, 1_000_000);
            assert!(g.u64(b) < b);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 4, |g| {
            let x = g.u64(10);
            assert!(x > 100, "x={x} is small");
        });
    }

    #[test]
    fn vec_has_requested_len() {
        check("vec len", 50, |g| {
            let n = g.usize(64);
            let v = g.vec(n, |g| g.u64(5));
            assert_eq!(v.len(), n);
        });
    }
}
