//! The per-core instruction-window model (Ramulator-style): a ROB of
//! `rob_size` entries retires up to `issue_width` completed
//! instructions per CPU cycle; memory instructions occupy their slot
//! until the cache hierarchy (or DRAM) answers, which naturally models
//! memory-level parallelism; `dependent` loads (pointer chasing)
//! block further issue entirely. Bulk copies are synchronous
//! (memcpy semantics): the core stops issuing until the copy
//! completes. OS bulk ops (`TraceOp::Bulk`) route through the OS
//! layer, whose outcomes reuse the same machinery: page-fault copies
//! stall the core exactly like synchronous bulk copies, then the
//! faulting access replays through the cache hierarchy.

use std::collections::VecDeque;

use crate::backend::{Access, MemoryModel};
use crate::config::CpuConfig;
use crate::controller::request::CopyRequest;
use crate::cpu::cache::Hierarchy;
use crate::cpu::trace::{Trace, TraceCursor, TraceOp};
use crate::os::{OsLayer, OsOutcome};

/// Request ids are partitioned per core; writebacks use the write id
/// space (no completion expected).
fn id_base(core: usize) -> u64 {
    (core as u64 + 1) << 32
}

/// A demand access headed to memory (cache lookup already done).
#[derive(Debug, Clone, Copy)]
struct Demand {
    addr: u64,
    is_write: bool,
    dependent: bool,
    latency: u64,
}

#[derive(Debug, Clone, Copy)]
enum Slot {
    /// Ready to retire at the given CPU cycle.
    ReadyAt(u64),
    /// Waiting for a memory read to complete.
    WaitMem(u64),
}

/// When a core next needs its `cycle()` to run (fast-forward support).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreWake {
    /// Would act on its very next CPU cycle — the engine must not
    /// skip anything.
    Active,
    /// Provably inert until the given absolute CPU cycle (a pipelined
    /// instruction becomes retirable then); only `cpu_cycles`
    /// bookkeeping happens before it.
    At(u64),
    /// Provably inert until an external completion (memory read or
    /// bulk copy) is delivered by the controller.
    Blocked,
}

/// Execution state of one core.
#[derive(Debug)]
pub struct Core {
    pub id: usize,
    trace: Trace,
    cursor: TraceCursor,
    window: VecDeque<Slot>,
    rob_size: usize,
    issue_width: u64,
    mshrs: usize,

    /// Non-memory instructions still to issue before the current op's
    /// action.
    nonmem_left: u32,
    cur_op: Option<TraceOp>,
    /// Demand access that passed the cache lookup but was rejected by
    /// the controller (queue full / MSHRs) and must be re-sent. The
    /// cache lookup itself happens exactly ONCE per op — re-running it
    /// would install the line on the first attempt and turn the retry
    /// into a phantom hit.
    pending_demand: Option<Demand>,
    /// Dirty-eviction writebacks waiting for write-queue space. These
    /// are not program-ordered; they drain lazily.
    wb_queue: VecDeque<u64>,
    outstanding: usize,
    dep_block: Option<u64>,
    /// Outstanding synchronous copies (a trace-level bulk copy, or the
    /// page copies of one OS bulk op / page fault): the core stops
    /// issuing until every one completes.
    wait_copies: Vec<u64>,
    next_id: u64,

    /// Ops consumed from the trace (budget accounting).
    pub mem_ops_done: u64,
    pub copies_done: u64,
    pub retired: u64,
    pub cpu_cycles: u64,
    /// Stop fetching new trace ops once the budget is consumed.
    pub budget: u64,
    fetch_stopped: bool,
}

impl Core {
    pub fn new(id: usize, trace: Trace, cfg: &CpuConfig, budget: u64) -> Self {
        Self {
            id,
            trace,
            cursor: TraceCursor::new(),
            window: VecDeque::with_capacity(cfg.rob_size),
            rob_size: cfg.rob_size,
            issue_width: cfg.issue_width,
            mshrs: cfg.mshrs,
            nonmem_left: 0,
            cur_op: None,
            pending_demand: None,
            wb_queue: VecDeque::new(),
            outstanding: 0,
            dep_block: None,
            wait_copies: Vec::new(),
            next_id: id_base(id),
            mem_ops_done: 0,
            copies_done: 0,
            retired: 0,
            cpu_cycles: 0,
            budget,
            fetch_stopped: false,
        }
    }

    /// All work finished (budget consumed and pipeline drained)?
    pub fn finished(&self) -> bool {
        self.fetch_stopped
            && self.window.is_empty()
            && self.wait_copies.is_empty()
            && self.pending_demand.is_none()
            && self.cur_op.is_none()
            && self.wb_queue.is_empty()
    }

    pub fn ipc(&self) -> f64 {
        if self.cpu_cycles == 0 {
            0.0
        } else {
            self.retired as f64 / self.cpu_cycles as f64
        }
    }

    fn alloc_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// A read completed in the memory system.
    pub fn on_mem_complete(&mut self, req_id: u64) {
        for s in self.window.iter_mut() {
            if let Slot::WaitMem(id) = s {
                if *id == req_id {
                    *s = Slot::ReadyAt(self.cpu_cycles);
                    break;
                }
            }
        }
        self.outstanding = self.outstanding.saturating_sub(1);
        if self.dep_block == Some(req_id) {
            self.dep_block = None;
        }
    }

    /// A synchronous copy completed.
    pub fn on_copy_complete(&mut self, copy_id: u64) {
        self.wait_copies.retain(|&id| id != copy_id);
    }

    /// One CPU cycle: retire, then issue. `os` carries the OS layer
    /// for workloads with `TraceOp::Bulk` records (None otherwise).
    pub fn cycle(
        &mut self,
        hier: &mut Hierarchy,
        mem: &mut dyn MemoryModel,
        mut os: Option<&mut OsLayer>,
    ) {
        if self.finished() {
            return;
        }
        self.cpu_cycles += 1;
        let now = self.cpu_cycles;

        // Drain lazy writebacks (not program-ordered).
        while let Some(&wb) = self.wb_queue.front() {
            let id = self.alloc_id();
            let addr = mem.map(wb);
            if mem.enqueue(Access::write(id, self.id, addr)) {
                self.wb_queue.pop_front();
            } else {
                self.next_id -= 1;
                break;
            }
        }

        // Retire.
        let mut retired = 0;
        while retired < self.issue_width {
            match self.window.front() {
                Some(Slot::ReadyAt(t)) if *t <= now => {
                    self.window.pop_front();
                    self.retired += 1;
                    retired += 1;
                }
                _ => break,
            }
        }

        if !self.wait_copies.is_empty() {
            return; // blocked on a synchronous copy / page fault
        }

        // Issue.
        let mut issued = 0;
        while issued < self.issue_width && self.window.len() < self.rob_size {
            if self.dep_block.is_some() {
                break;
            }
            // Re-send a previously rejected demand access first (the
            // cache lookup for it is already done).
            if let Some(d) = self.pending_demand {
                if !self.send_demand(d, mem, now) {
                    break;
                }
                self.pending_demand = None;
                issued += 1;
                continue;
            }
            if self.nonmem_left > 0 {
                self.nonmem_left -= 1;
                self.window.push_back(Slot::ReadyAt(now + 1));
                issued += 1;
                continue;
            }
            // Current op's action is due.
            if let Some(op) = self.cur_op.take() {
                if !self.do_action(op, hier, mem, os.as_deref_mut(), now) {
                    break; // demand parked in pending_demand
                }
                issued += 1;
                if !self.wait_copies.is_empty() {
                    break;
                }
                continue;
            }
            // Fetch the next trace op.
            if self.fetch_stopped {
                break;
            }
            let op = self.cursor.next(&self.trace);
            self.nonmem_left = op.nonmem();
            self.cur_op = Some(op);
            let consumed = self.mem_ops_done + self.copies_done + 1;
            if consumed >= self.budget {
                self.fetch_stopped = true;
            }
        }
    }

    /// When does this core next need to run? Mirrors `cycle()`'s
    /// decision order exactly: any path that would mutate core, cache
    /// or controller state on the next CPU cycle reports `Active`;
    /// otherwise the core is inert until either a wall-clock wake
    /// (`At`: the front ROB slot's ready time) or an external
    /// completion (`Blocked`). While inert, `cycle()` is a pure
    /// `cpu_cycles += 1`, which `advance_idle` replays in bulk.
    pub fn next_wake(&self, mem: &dyn MemoryModel) -> CoreWake {
        if self.finished() {
            return CoreWake::Blocked; // never runs again (drive loop exits)
        }
        // The CPU cycle the next `cycle()` call will execute as.
        let next = self.cpu_cycles + 1;
        // Lazy writebacks: an acceptable head would be enqueued. A
        // rejected head is retried (and re-rejected) with no net state
        // change until the controller's write queue drains — a
        // controller-side event.
        if let Some(&wb) = self.wb_queue.front() {
            if mem.can_accept(mem.map(wb).channel, true) {
                return CoreWake::Active;
            }
        }
        // Retirement: the front slot gates everything.
        let mut wake: Option<u64> = None;
        if let Some(Slot::ReadyAt(t)) = self.window.front() {
            if *t <= next {
                return CoreWake::Active;
            }
            wake = Some(*t);
        }
        let wake_or_blocked = |w: Option<u64>| w.map_or(CoreWake::Blocked, CoreWake::At);
        if !self.wait_copies.is_empty() {
            return wake_or_blocked(wake);
        }
        // Issue stage, in `cycle()`'s check order.
        if self.window.len() >= self.rob_size || self.dep_block.is_some() {
            return wake_or_blocked(wake);
        }
        if let Some(d) = self.pending_demand {
            let ch = mem.map(d.addr).channel;
            let sendable = if d.is_write {
                mem.can_accept(ch, true)
            } else {
                self.outstanding < self.mshrs && mem.can_accept(ch, false)
            };
            return if sendable {
                CoreWake::Active
            } else {
                wake_or_blocked(wake)
            };
        }
        if self.nonmem_left > 0 || self.cur_op.is_some() || !self.fetch_stopped {
            return CoreWake::Active;
        }
        wake_or_blocked(wake)
    }

    /// Account for `cpu_cycles` provably inert CPU cycles in one step
    /// (the engine established inertness via `next_wake`). Finished
    /// cores stop their clock, exactly as `cycle()`'s early return
    /// does.
    pub fn advance_idle(&mut self, cpu_cycles: u64) {
        if !self.finished() {
            self.cpu_cycles += cpu_cycles;
        }
    }

    /// Try to send a demand access to the controller; false if it must
    /// be re-sent later (the caller parks it in `pending_demand`).
    fn send_demand(&mut self, d: Demand, mem: &mut dyn MemoryModel, now: u64) -> bool {
        if d.is_write {
            // Stores are posted: retire once the write is accepted.
            let id = self.alloc_id();
            let addr = mem.map(d.addr);
            if !mem.enqueue(Access::write(id, self.id, addr)) {
                self.next_id -= 1;
                return false;
            }
            self.window.push_back(Slot::ReadyAt(now + d.latency));
            return true;
        }
        if self.outstanding >= self.mshrs {
            return false;
        }
        let id = self.alloc_id();
        let addr = mem.map(d.addr);
        if !mem.enqueue(Access::read(id, self.id, addr)) {
            self.next_id -= 1;
            return false;
        }
        self.outstanding += 1;
        self.window.push_back(Slot::WaitMem(id));
        if d.dependent {
            self.dep_block = Some(id);
        }
        true
    }

    /// Perform one memory access (cache lookup exactly once, then the
    /// demand path); false if the demand was parked for re-sending.
    fn mem_action(
        &mut self,
        addr: u64,
        is_write: bool,
        dependent: bool,
        hier: &mut Hierarchy,
        mem: &mut dyn MemoryModel,
        now: u64,
    ) -> bool {
        // The cache lookup happens exactly once per op.
        let acc = hier.access(self.id, addr, is_write);
        self.mem_ops_done += 1;
        // Dirty evictions that reached memory become lazy posted
        // writes.
        self.wb_queue.extend(acc.writebacks.iter().copied());
        if !acc.goes_to_memory {
            self.window.push_back(Slot::ReadyAt(now + acc.latency));
            return true;
        }
        let d = Demand { addr, is_write, dependent, latency: acc.latency };
        if self.send_demand(d, mem, now) {
            true
        } else {
            self.pending_demand = Some(d);
            false
        }
    }

    /// Execute a trace op's action; false if its demand access was
    /// parked for re-sending (cache lookups are never repeated).
    fn do_action(
        &mut self,
        op: TraceOp,
        hier: &mut Hierarchy,
        mem: &mut dyn MemoryModel,
        os: Option<&mut OsLayer>,
        now: u64,
    ) -> bool {
        match op {
            TraceOp::Mem { addr, is_write, dependent, .. } => {
                self.mem_action(addr, is_write, dependent, hier, mem, now)
            }
            TraceOp::Bulk { op, .. } => {
                let outcome = match os {
                    Some(os) => os.execute(self.id, op, mem),
                    // No OS layer wired up: the primitive is a no-op
                    // (non-OS harnesses replaying an OS trace).
                    None => OsOutcome::Done,
                };
                match outcome {
                    OsOutcome::Done => {
                        self.window.push_back(Slot::ReadyAt(now + 1));
                        self.copies_done += 1;
                        true
                    }
                    OsOutcome::Stall(ids) => {
                        self.window.push_back(Slot::ReadyAt(now + 1));
                        self.wait_copies = ids;
                        self.copies_done += 1;
                        true
                    }
                    OsOutcome::Access { addr, is_write, dependent } => {
                        self.mem_action(addr, is_write, dependent, hier, mem, now)
                    }
                    OsOutcome::FaultThenAccess { copies, addr, is_write, dependent } => {
                        // The faulting instruction stalls on the page
                        // copies; the translated access then replays as
                        // a synthetic Mem op (cache lookup included).
                        self.window.push_back(Slot::ReadyAt(now + 1));
                        self.wait_copies = copies;
                        self.cur_op = Some(TraceOp::Mem {
                            nonmem: 0,
                            addr,
                            is_write,
                            dependent,
                        });
                        true
                    }
                }
            }
            TraceOp::Copy { src, dst, rows, .. } => {
                let id = self.alloc_id();
                let src_a = {
                    let mut a = mem.map(src);
                    a.col = 0;
                    a
                };
                let dst_a = {
                    let mut a = mem.map(dst);
                    a.col = 0;
                    a
                };
                mem.enqueue_copy(CopyRequest {
                    id,
                    core: self.id,
                    src: src_a,
                    dst: dst_a,
                    rows: rows as usize,
                    mechanism: mem.cfg().copy_mechanism,
                    arrive: mem.now(),
                });
                self.window.push_back(Slot::ReadyAt(now + 1));
                self.wait_copies = vec![id];
                self.copies_done += 1;
                true
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::controller::Controller;
    use crate::cpu::trace::TraceOp;

    fn mk(trace: Vec<TraceOp>, budget: u64) -> (Core, Hierarchy, Controller) {
        let cfg = SimConfig::default();
        let core = Core::new(0, Trace::new(trace), &cfg.cpu, budget);
        let hier = Hierarchy::new(&cfg.cpu);
        let ctrl = Controller::new(cfg);
        (core, hier, ctrl)
    }

    fn run(core: &mut Core, hier: &mut Hierarchy, ctrl: &mut Controller, max: u64) {
        let ratio = ctrl.cfg.cpu.clock_ratio;
        for _ in 0..max {
            ctrl.tick().unwrap();
            for c in ctrl.drain_completions() {
                if c.was_copy {
                    core.on_copy_complete(c.id);
                } else {
                    core.on_mem_complete(c.id);
                }
            }
            for _ in 0..ratio {
                core.cycle(hier, ctrl, None);
            }
            if core.finished() && ctrl.idle() {
                break;
            }
        }
    }

    #[test]
    fn core_retires_all_instructions() {
        let trace = vec![TraceOp::Mem {
            nonmem: 9,
            addr: 0x4000,
            is_write: false,
            dependent: false,
        }];
        let (mut core, mut hier, mut ctrl) = mk(trace, 5);
        run(&mut core, &mut hier, &mut ctrl, 100_000);
        assert!(core.finished());
        assert_eq!(core.mem_ops_done, 5);
        assert_eq!(core.retired, 50); // 5 ops * (9 nonmem + 1 mem)
        assert!(core.ipc() > 0.0);
    }

    #[test]
    fn cache_hits_do_not_reach_memory() {
        // Same line over and over: one memory fetch, the rest L1 hits.
        let trace = vec![TraceOp::Mem {
            nonmem: 0,
            addr: 0x8000,
            is_write: false,
            dependent: false,
        }];
        let (mut core, mut hier, mut ctrl) = mk(trace, 100);
        run(&mut core, &mut hier, &mut ctrl, 200_000);
        assert!(core.finished());
        assert_eq!(ctrl.stats.reads_done, 1, "only the first access misses");
    }

    #[test]
    fn dependent_loads_serialize() {
        // Two independent-load traces vs dependent-load traces over
        // distinct rows: the dependent one must take longer.
        let mk_trace = |dependent| {
            (0..8)
                .map(|i| TraceOp::Mem {
                    nonmem: 0,
                    // Distinct banks (8 KB apart): independent loads
                    // can overlap their activations across banks.
                    addr: 0x10_0000 + i * 0x2000,
                    is_write: false,
                    dependent,
                })
                .collect::<Vec<_>>()
        };
        let (mut c1, mut h1, mut ctl1) = mk(mk_trace(false), 8);
        run(&mut c1, &mut h1, &mut ctl1, 500_000);
        let (mut c2, mut h2, mut ctl2) = mk(mk_trace(true), 8);
        run(&mut c2, &mut h2, &mut ctl2, 500_000);
        assert!(c1.finished() && c2.finished());
        assert!(
            c2.cpu_cycles > c1.cpu_cycles,
            "dependent {} <= parallel {}",
            c2.cpu_cycles,
            c1.cpu_cycles
        );
    }

    #[test]
    fn copy_blocks_until_done() {
        let trace = vec![
            TraceOp::Copy { nonmem: 0, src: 0, dst: 0x40000, rows: 1 },
            TraceOp::Mem { nonmem: 0, addr: 0x80000, is_write: false, dependent: false },
        ];
        let (mut core, mut hier, mut ctrl) = mk(trace, 2);
        run(&mut core, &mut hier, &mut ctrl, 500_000);
        assert!(core.finished());
        assert_eq!(core.copies_done, 1);
        assert_eq!(core.mem_ops_done, 1);
        assert_eq!(ctrl.stats.copies_done, 1);
    }

    #[test]
    fn bulk_ops_fault_and_stall_through_the_os_layer() {
        use crate::cpu::trace::BulkOp;
        let trace = vec![
            TraceOp::Bulk { nonmem: 0, op: BulkOp::Zero { va: 0, pages: 2 } },
            TraceOp::Bulk { nonmem: 0, op: BulkOp::Fork },
            TraceOp::Bulk {
                nonmem: 0,
                op: BulkOp::Touch { va: 64, is_write: true, dependent: false },
            },
        ];
        let cfg = SimConfig::default();
        let mut core = Core::new(0, Trace::new(trace), &cfg.cpu, 3);
        let mut hier = Hierarchy::new(&cfg.cpu);
        let mut ctrl = Controller::new(cfg.clone());
        let mut os = OsLayer::new(&cfg);
        let ratio = ctrl.cfg.cpu.clock_ratio;
        for _ in 0..500_000u64 {
            ctrl.tick().unwrap();
            for c in ctrl.drain_completions() {
                if c.was_copy {
                    core.on_copy_complete(c.id);
                } else {
                    core.on_mem_complete(c.id);
                }
            }
            for _ in 0..ratio {
                core.cycle(&mut hier, &mut ctrl, Some(&mut os));
            }
            if core.finished() && ctrl.idle() {
                break;
            }
        }
        assert!(core.finished());
        // Zero (2 pages) + CoW break (1 page) all went through DRAM.
        assert_eq!(os.stats.pages_zeroed, 2);
        assert_eq!(os.stats.cow_faults, 1);
        assert_eq!(ctrl.stats.copies_done, 3);
        assert_eq!(os.stats.forks, 1);
        // The faulting touch replayed as a real memory access.
        assert_eq!(core.mem_ops_done, 1);
        // Zero + fork consumed the copy-op budget slots.
        assert_eq!(core.copies_done, 2);
    }

    #[test]
    fn stores_are_posted() {
        let trace = vec![TraceOp::Mem {
            nonmem: 0,
            addr: 0x9000,
            is_write: true,
            dependent: false,
        }];
        let (mut core, mut hier, mut ctrl) = mk(trace, 4);
        run(&mut core, &mut hier, &mut ctrl, 200_000);
        assert!(core.finished());
        // Store hits in L1 after the first allocation; nothing blocks.
        assert!(core.cpu_cycles < 1000);
    }
}
