//! Minimal leveled logger (stderr). The simulator hot path never logs;
//! logging is for the CLI driver, calibration and experiment harnesses.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Resolve the effective level from CLI verbosity counts and the
/// `LISA_LOG` environment value (pure, so it is unit-testable):
/// `-q` wins over `-v`, both win over the environment, and an
/// unrecognized environment string falls back to `Info`.
pub fn resolve(verbose: u32, quiet: u32, env: Option<&str>) -> Level {
    if quiet > 0 {
        return Level::Error;
    }
    if verbose > 0 {
        return Level::Debug;
    }
    match env.map(str::trim).map(str::to_ascii_lowercase).as_deref() {
        Some("error") => Level::Error,
        Some("warn") => Level::Warn,
        Some("debug") => Level::Debug,
        _ => Level::Info,
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Info) {
            eprintln!("[info] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Warn) {
            eprintln!("[warn] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            eprintln!("[debug] {}", format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn resolve_precedence_and_env_fallback() {
        // Flags beat the environment; quiet beats verbose.
        assert_eq!(resolve(1, 0, Some("error")), Level::Debug);
        assert_eq!(resolve(0, 1, Some("debug")), Level::Error);
        assert_eq!(resolve(2, 1, None), Level::Error);
        // Environment fallback, case/whitespace-insensitive.
        assert_eq!(resolve(0, 0, Some("warn")), Level::Warn);
        assert_eq!(resolve(0, 0, Some(" DEBUG ")), Level::Debug);
        assert_eq!(resolve(0, 0, Some("error")), Level::Error);
        assert_eq!(resolve(0, 0, Some("info")), Level::Info);
        // Unrecognized or absent -> Info.
        assert_eq!(resolve(0, 0, Some("chatty")), Level::Info);
        assert_eq!(resolve(0, 0, None), Level::Info);
    }
}
