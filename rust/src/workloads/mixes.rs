//! The evaluation workload suite: 50 four-core copy mixes (experiments
//! E5/E6/E7), hot-region mixes for LISA-VILLA (E4), and a handful of
//! microbenchmark workloads for the examples.
//!
//! Mix construction mirrors the paper's methodology: each mix pairs
//! copy-intensive cores (fork / bootup / compile / memcached-class
//! behaviour with varying copy sizes, periods and hop distances) with
//! memory-intensive background cores drawn from the stream / random /
//! pointer-chase / hotspot classes. Everything is deterministic in the
//! mix index.

use anyhow::{bail, Result};

use crate::config::SimConfig;
use crate::cpu::trace::Trace;
use crate::trace::TraceSource;
use crate::util::rng::Pcg32;
use crate::workloads::gc::GcScenario;
use crate::workloads::generators::{CoreSpec, WorkloadKind};
use crate::workloads::os_scenarios::OsScenario;

/// A named multi-core workload. Synthetic workloads carry per-core
/// generator specs; trace-backed workloads (`source`) replay recorded
/// op streams from a trace file instead.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub cores: Vec<CoreSpec>,
    /// When set, `traces()` decodes the recorded per-core streams from
    /// this file; `cores` then only fixes the core count (placeholder
    /// specs). Built via `crate::trace::workload_from_file`, which
    /// validates the whole file up front.
    pub source: Option<TraceSource>,
}

impl Workload {
    /// Generate per-core traces (n_ops each; recorded traces keep
    /// their recorded length — cores replay them cyclically).
    pub fn traces(&self, cfg: &SimConfig, n_ops: usize) -> Vec<Trace> {
        if let Some(src) = &self.source {
            // The file was fully validated when the workload was
            // built, so a decode failure here means it changed or
            // vanished mid-run — fail loudly, never simulate garbage.
            return src.load_traces().unwrap_or_else(|e| {
                panic!("trace workload '{}': {e:#}", self.name)
            });
        }
        self.cores
            .iter()
            .enumerate()
            .map(|(core, spec)| spec.generate(cfg, core, n_ops, hash_name(&self.name)))
            .collect()
    }
}

fn hash_name(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The copy-workload background classes.
fn background(rng: &mut Pcg32) -> CoreSpec {
    let kinds = [
        WorkloadKind::Stream { stride: 1 },
        WorkloadKind::Stream { stride: 4 },
        WorkloadKind::Random,
        WorkloadKind::PointerChase,
        WorkloadKind::HotSpot { hot_bytes: 12 << 20, hot_frac: 0.85, dep_frac: 0.3 },
    ];
    let kind = *rng.pick(&kinds);
    CoreSpec {
        kind,
        wss: (10u64 + rng.below(22)) << 20,
        nonmem: 2 + rng.below(14) as u32,
        write_frac: 0.1 + rng.f64() * 0.3,
    }
}

/// Copy-intensive core classes (fork / bootup / compile / memcached).
fn copy_core(rng: &mut Pcg32) -> CoreSpec {
    // Copy intensity tuned so bulk copies consume roughly half of the
    // baseline's runtime (the regime the paper's 50 mixes sit in:
    // LISA-RISC alone buys ~+60%).
    let rows = *rng.pick(&[1u32, 2, 4]);
    let period = *rng.pick(&[150u32, 300, 600, 1200]);
    // Hop distance class: near (1-2 hops), mid (4-8), far (8-15).
    let hop_rows = *rng.pick(&[512u64, 1024, 2048, 4096, 7680]);
    CoreSpec {
        kind: WorkloadKind::BulkCopy { rows, period, hop_rows },
        wss: (16u64 + rng.below(48)) << 20,
        nonmem: 2 + rng.below(8) as u32,
        write_frac: 0.2,
    }
}

/// The 50 four-core copy mixes of §3.1.2 / Fig. 4: mix i has
/// 1 + (i mod 3) copy-intensive cores, rest background.
pub fn copy_mixes(cores: usize) -> Vec<Workload> {
    (0..50)
        .map(|i| {
            let mut rng = Pcg32::new(0x50_C0DE, i as u64);
            let n_copy = 1 + (i % 3).min(cores - 1);
            let mut specs: Vec<CoreSpec> =
                (0..n_copy).map(|_| copy_core(&mut rng)).collect();
            while specs.len() < cores {
                specs.push(background(&mut rng));
            }
            Workload { name: format!("copy-mix-{i:02}"), cores: specs, source: None }
        })
        .collect()
}

/// Hot-region mixes for LISA-VILLA (Fig. 3): varying skew and hot-set
/// sizes; higher skew => higher VILLA hit rate => more benefit.
pub fn villa_mixes(cores: usize) -> Vec<Workload> {
    // Hot regions must exceed the 8 MB LLC so the row heat reaches
    // DRAM (where VILLA operates); skew varies the achievable hit rate
    // (Fig. 3's x-axis spread).
    let params = [
        (12u64 << 20, 0.95, "tiny-hot"),
        (16 << 20, 0.90, "small-hot"),
        (20 << 20, 0.85, "med-hot"),
        (24 << 20, 0.80, "large-hot"),
        (32 << 20, 0.70, "xl-hot"),
        (16 << 20, 0.95, "sharp-hot"),
        (40 << 20, 0.60, "flat-hot"),
        (12 << 20, 0.99, "needle-hot"),
    ];
    params
        .iter()
        .enumerate()
        .map(|(i, &(hot_bytes, hot_frac, name))| {
            let mut rng = Pcg32::new(0x7111A, i as u64);
            let specs: Vec<CoreSpec> = (0..cores)
                .map(|_| CoreSpec {
                    kind: WorkloadKind::HotSpot { hot_bytes, hot_frac, dep_frac: 0.6 },
                    wss: hot_bytes + ((8u64 + rng.below(16)) << 20),
                    nonmem: 8 + rng.below(10) as u32,
                    write_frac: 0.15,
                })
                .collect();
            Workload { name: format!("villa-{name}"), cores: specs, source: None }
        })
        .collect()
}

/// Simple single-class workloads for the examples and smoke tests.
pub fn micro_workloads(cores: usize) -> Vec<Workload> {
    let mk = |name: &str, kind: WorkloadKind, nonmem: u32| Workload {
        name: name.to_string(),
        cores: (0..cores)
            .map(|_| CoreSpec { kind, wss: 24 << 20, nonmem, write_frac: 0.2 })
            .collect(),
        source: None,
    };
    vec![
        mk("stream4", WorkloadKind::Stream { stride: 1 }, 4),
        mk("random4", WorkloadKind::Random, 4),
        mk("chase4", WorkloadKind::PointerChase, 8),
        mk(
            "hotspot4",
            WorkloadKind::HotSpot { hot_bytes: 16 << 20, hot_frac: 0.9, dep_frac: 0.6 },
            8,
        ),
        mk(
            "fork4",
            WorkloadKind::BulkCopy { rows: 4, period: 60, hop_rows: 2048 },
            4,
        ),
    ]
}

/// Intra-bank-conflict workloads for the SALP/MASA substrate (E10):
/// request streams that ping-pong between subarrays of one bank, so
/// the parallelism mode visibly changes row-buffer behaviour. All of
/// them keep off subarray 0 (`first_sa >= 2`) so they compose with
/// VILLA's promotion subarray.
pub fn salp_mixes(cores: usize) -> Vec<Workload> {
    let pingpong = |first_sa: u32, subarrays: u32, rows: u32, burst: u32, bank| CoreSpec {
        kind: WorkloadKind::SubarrayPingPong { subarrays, first_sa, rows, burst, bank },
        wss: 0, // raw physical addressing; working set is sa x rows x 8 KB
        nonmem: 2,
        write_frac: 0.2,
    };
    vec![
        // Every core ping-pongs 4 subarrays of its own bank: pure
        // intra-bank conflicts, no cross-core interference.
        Workload {
            name: "salp-pingpong4".into(),
            cores: (0..cores).map(|_| pingpong(2, 4, 16, 8, None)).collect(),
            source: None,
        },
        // All cores share bank 0 in disjoint subarray ranges: the
        // cross-core version of the same conflict (the MASA headline).
        Workload {
            name: "salp-shared-bank4".into(),
            cores: (0..cores)
                .map(|i| pingpong(2 + 3 * (i as u32 % 4), 3, 32, 4, Some(0)))
                .collect(),
            source: None,
        },
        // Bulk copies and subarray ping-pong fighting over the same
        // banks: exercises the copy-vs-open-row exclusion rules and
        // the LISA link path under every parallelism mode.
        Workload {
            name: "salp-copy-conflict4".into(),
            cores: (0..cores)
                .map(|i| {
                    if i < 2 {
                        CoreSpec {
                            kind: WorkloadKind::BulkCopy {
                                rows: 2,
                                period: 80,
                                hop_rows: 2048,
                            },
                            wss: 24 << 20,
                            nonmem: 4,
                            write_frac: 0.2,
                        }
                    } else {
                        pingpong(8, 4, 8, 8, Some((i as u32) - 2))
                    }
                })
                .collect(),
            source: None,
        },
    ]
}

/// The four OS-scenario workloads of experiment E9 (every core runs
/// its own process instance of the scenario).
pub fn os_workloads(cores: usize) -> Vec<Workload> {
    // For `Os` kinds only `nonmem` is read by the generator; working
    // set and write mix are scenario parameters (page counts / touch
    // ratios inside `OsScenario`), so `wss`/`write_frac` are zeroed to
    // make that explicit.
    let mk = |name: &str, scn: OsScenario, nonmem: u32| Workload {
        name: name.to_string(),
        cores: (0..cores)
            .map(|_| CoreSpec {
                kind: WorkloadKind::Os(scn),
                wss: 0,
                nonmem,
                write_frac: 0.0,
            })
            .collect(),
        source: None,
    };
    vec![
        mk("os-fork", OsScenario::ForkServer { pages: 64, period: 96 }, 4),
        mk(
            "os-zero",
            OsScenario::BootZero { region_pages: 16, regions: 8, period: 64 },
            4,
        ),
        mk("os-checkpoint", OsScenario::Checkpoint { pages: 96, period: 128 }, 4),
        mk("os-promote", OsScenario::HotPromote { pages: 128, hot: 8, period: 64 }, 6),
    ]
}

/// The GC / heap-traversal workloads of experiment E11 (every core
/// runs its own collector instance; see `workloads/gc`).
pub fn gc_workloads(cores: usize) -> Vec<Workload> {
    // Like the OS workloads, `wss`/`write_frac` are scenario-internal
    // (page counts and chase write rates), so the spec zeroes them.
    let mk = |name: &str, scn: GcScenario, nonmem: u32| Workload {
        name: name.to_string(),
        cores: (0..cores)
            .map(|_| CoreSpec {
                kind: WorkloadKind::Gc(scn),
                wss: 0,
                nonmem,
                write_frac: 0.0,
            })
            .collect(),
        source: None,
    };
    vec![
        mk("gc-chase", GcScenario::Traverse { pages: 192, sites: 12 }, 6),
        mk(
            "gc-semispace",
            GcScenario::Semispace { pages: 96, sites: 8, period: 96, evac_pages: 24 },
            4,
        ),
        mk(
            "gc-mark",
            GcScenario::ConcurrentMark { pages: 128, sites: 8, period: 96 },
            4,
        ),
        mk(
            "gc-gen",
            GcScenario::Generational {
                nursery_pages: 48,
                old_pages: 96,
                period: 96,
                survivors: 8,
            },
            4,
        ),
    ]
}

/// Every named workload in the suite.
pub fn all_mixes(cfg: &SimConfig) -> Vec<Workload> {
    let cores = cfg.cpu.cores;
    let mut out = micro_workloads(cores);
    out.extend(villa_mixes(cores));
    out.extend(salp_mixes(cores));
    out.extend(os_workloads(cores));
    out.extend(gc_workloads(cores));
    out.extend(copy_mixes(cores));
    out
}

/// Look up a workload by name.
pub fn workload_by_name(name: &str, cfg: &SimConfig) -> Result<Workload> {
    all_mixes(cfg)
        .into_iter()
        .find(|w| w.name == name)
        .map_or_else(|| bail!("unknown workload '{name}'"), Ok)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::trace::TraceOp;

    #[test]
    fn suite_has_50_copy_mixes() {
        let mixes = copy_mixes(4);
        assert_eq!(mixes.len(), 50);
        for m in &mixes {
            assert_eq!(m.cores.len(), 4);
            // Every copy mix has at least one copy-intensive core.
            assert!(m
                .cores
                .iter()
                .any(|c| matches!(c.kind, WorkloadKind::BulkCopy { .. })));
        }
        // Mixes differ from each other.
        assert_ne!(
            format!("{:?}", mixes[0].cores),
            format!("{:?}", mixes[1].cores)
        );
    }

    #[test]
    fn traces_are_deterministic_per_name() {
        let cfg = SimConfig::default();
        let w = workload_by_name("copy-mix-00", &cfg).unwrap();
        let a = w.traces(&cfg, 200);
        let b = w.traces(&cfg, 200);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.ops, y.ops);
        }
    }

    #[test]
    fn lookup_by_name() {
        let cfg = SimConfig::default();
        assert!(workload_by_name("stream4", &cfg).is_ok());
        assert!(workload_by_name("villa-med-hot", &cfg).is_ok());
        assert!(workload_by_name("nope", &cfg).is_err());
    }

    #[test]
    fn copy_mixes_emit_copies() {
        let cfg = SimConfig::default();
        let w = workload_by_name("copy-mix-03", &cfg).unwrap();
        // Periods can be up to 1200 background ops per copy.
        let traces = w.traces(&cfg, 3000);
        let total_copies: usize = traces
            .iter()
            .map(|t| {
                t.ops
                    .iter()
                    .filter(|o| matches!(o, TraceOp::Copy { .. }))
                    .count()
            })
            .sum();
        assert!(total_copies > 0);
    }

    #[test]
    fn os_workloads_registered_and_bulk_bearing() {
        let cfg = SimConfig::default();
        for name in ["os-fork", "os-zero", "os-checkpoint", "os-promote"] {
            let w = workload_by_name(name, &cfg).unwrap();
            assert_eq!(w.cores.len(), 4);
            let traces = w.traces(&cfg, 300);
            assert!(
                traces.iter().all(|t| t.needs_os()),
                "{name}: every core must carry OS bulk ops"
            );
        }
    }

    #[test]
    fn gc_workloads_registered_and_bulk_bearing() {
        let cfg = SimConfig::default();
        for name in ["gc-chase", "gc-semispace", "gc-mark", "gc-gen"] {
            let w = workload_by_name(name, &cfg).unwrap();
            assert_eq!(w.cores.len(), 4);
            let traces = w.traces(&cfg, 300);
            assert!(
                traces.iter().all(|t| t.needs_os()),
                "{name}: every core must carry OS bulk ops"
            );
        }
    }

    #[test]
    fn salp_mixes_target_single_banks_across_subarrays() {
        use crate::controller::mapping::{Mapper, MappingScheme};
        let cfg = SimConfig::default();
        let m = Mapper::new(&cfg.dram, MappingScheme::RowRankBankColCh);
        for name in ["salp-pingpong4", "salp-shared-bank4", "salp-copy-conflict4"] {
            assert!(workload_by_name(name, &cfg).is_ok(), "{name} not registered");
        }
        // Shared-bank mix: every core stays in bank 0 but uses its own
        // disjoint subarray range — intra-bank, cross-core conflicts.
        let w = workload_by_name("salp-shared-bank4", &cfg).unwrap();
        let traces = w.traces(&cfg, 600);
        let mut per_core_sas: Vec<std::collections::BTreeSet<usize>> = Vec::new();
        for t in &traces {
            let mut sas = std::collections::BTreeSet::new();
            for o in &t.ops {
                if let TraceOp::Mem { addr, .. } = o {
                    let a = m.map(*addr);
                    assert_eq!(a.bank, 0, "shared-bank mix must stay in bank 0");
                    sas.insert(a.row / cfg.dram.rows_per_subarray);
                }
            }
            assert!(sas.len() >= 2, "core must ping-pong >= 2 subarrays: {sas:?}");
            assert!(!sas.contains(&0), "subarray 0 is reserved for VILLA promotion");
            per_core_sas.push(sas);
        }
        for i in 0..per_core_sas.len() {
            for j in (i + 1)..per_core_sas.len() {
                assert!(
                    per_core_sas[i].is_disjoint(&per_core_sas[j]),
                    "cores {i}/{j} share subarrays"
                );
            }
        }
        // Per-bank mix: each core owns its own bank.
        let w = workload_by_name("salp-pingpong4", &cfg).unwrap();
        let traces = w.traces(&cfg, 200);
        for (core, t) in traces.iter().enumerate() {
            for o in &t.ops {
                if let TraceOp::Mem { addr, .. } = o {
                    assert_eq!(m.map(*addr).bank, core % cfg.dram.banks);
                }
            }
        }
    }

    #[test]
    fn villa_mixes_are_hot_skewed() {
        let mixes = villa_mixes(4);
        assert_eq!(mixes.len(), 8);
        for m in &mixes {
            for c in &m.cores {
                assert!(matches!(c.kind, WorkloadKind::HotSpot { .. }));
            }
        }
    }
}
