//! Command-line argument parsing (no clap offline): subcommand +
//! `--key value` / `--flag` options + positionals.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. The first non-option token is the
    /// subcommand; `--key value` pairs become options; a `--key`
    /// followed by another `--` token or end-of-line is a flag.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let tokens: Vec<String> = argv.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(key) = t.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' is not supported");
                }
                // `--key=value` form
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    out.options.insert(key.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(t.clone());
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn opt_u64(&self, key: &str) -> Result<Option<u64>> {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => Ok(Some(s.parse()?)),
        }
    }

    pub fn opt_usize(&self, key: &str) -> Result<Option<usize>> {
        Ok(self.opt_u64(key)?.map(|v| v as usize))
    }

    pub fn opt_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => Ok(Some(s.parse()?)),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("run --workload stream4 --seed 7 trailing");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.opt("workload"), Some("stream4"));
        assert_eq!(a.opt_u64("seed").unwrap(), Some(7));
        assert_eq!(a.positional, vec!["trailing".to_string()]);
    }

    #[test]
    fn eq_form_and_flags() {
        let a = parse("bench --mech=lisa-risc --verbose");
        assert_eq!(a.opt("mech"), Some("lisa-risc"));
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn flag_before_option() {
        let a = parse("x --flag --k v");
        assert!(a.has_flag("flag"));
        assert_eq!(a.opt("k"), Some("v"));
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("x --n abc");
        assert!(a.opt_u64("n").is_err());
    }
}
