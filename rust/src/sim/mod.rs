//! Simulation engine (CPU ⇄ controller ⇄ DRAM binding) and the
//! experiment drivers that regenerate the paper's tables and figures.

pub mod engine;
pub mod experiments;

pub use engine::Simulation;
