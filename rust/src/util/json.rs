//! Minimal JSON parser — the read half of the hand-rolled emitters in
//! `metrics::json` (the offline registry has no serde). Used by the
//! campaign checkpoint journal and the content-addressed result cache
//! to round-trip finished records back into memory.
//!
//! Numbers keep their raw token text and are parsed on access, so u64
//! counters re-read exactly and floats round-trip bit-exact through
//! Rust's shortest-repr `Display` (what `metrics::json::number`
//! emits). Object key order is preserved — the emitters write fixed
//! field orders and byte-identical re-serialization depends on it.

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// Raw number token (e.g. `"-2.5e-3"`), parsed on access.
    Number(String),
    Str(String),
    Array(Vec<Value>),
    /// Key/value pairs in document order.
    Object(Vec<(String, Value)>),
}

impl Value {
    /// Object field lookup (first match); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(fields) => {
                fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Number(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(s) => s.parse().ok(),
            _ => None,
        }
    }

    /// Number-or-null accessor for the emitters' convention of writing
    /// non-finite floats as `null` (JSON has no NaN/Infinity tokens):
    /// `null` reads back as NaN, which re-serializes as `null`.
    pub fn as_f64_or_nan(&self) -> Option<f64> {
        match self {
            Value::Null => Some(f64::NAN),
            v => v.as_f64(),
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Object(fields) => Some(fields),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }
}

/// Parse one complete JSON document; trailing non-whitespace is an
/// error (a torn journal line must not parse as its prefix).
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            match b {
                b' ' | b'\t' | b'\n' | b'\r' => self.pos += 1,
                _ => break,
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos);
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos);
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => bail!("unexpected input at byte {}", self.pos),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            fields.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(fields));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            match b {
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E' => self.pos += 1,
                _ => break,
            }
        }
        let token = std::str::from_utf8(&self.bytes[start..self.pos])
            .expect("number bytes are ASCII");
        if token.parse::<f64>().is_err() {
            bail!("malformed number '{token}' at byte {start}");
        }
        Ok(Value::Number(token.to_string()))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        // Build as bytes so multi-byte UTF-8 passes through untouched;
        // the input is a valid &str and every escape emits valid UTF-8.
        let mut out: Vec<u8> = Vec::new();
        loop {
            let Some(b) = self.peek() else {
                bail!("unterminated string");
            };
            self.pos += 1;
            match b {
                b'"' => {
                    return Ok(String::from_utf8(out).expect("escapes keep UTF-8"));
                }
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        bail!("unterminated escape");
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push(b'"'),
                        b'\\' => out.push(b'\\'),
                        b'/' => out.push(b'/'),
                        b'n' => out.push(b'\n'),
                        b'r' => out.push(b'\r'),
                        b't' => out.push(b'\t'),
                        b'b' => out.push(0x08),
                        b'f' => out.push(0x0c),
                        b'u' => {
                            let c = self.unicode_escape()?;
                            let mut buf = [0u8; 4];
                            out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
                        }
                        other => bail!("unknown escape '\\{}'", other as char),
                    }
                }
                other => out.push(other),
            }
        }
    }

    /// The four hex digits after `\u`, including surrogate pairs.
    fn unicode_escape(&mut self) -> Result<char> {
        let first = self.hex4()?;
        let code = if (0xd800..0xdc00).contains(&first) {
            // High surrogate: a `\uXXXX` low surrogate must follow.
            if self.peek() != Some(b'\\') {
                bail!("lone high surrogate");
            }
            self.pos += 1;
            if self.peek() != Some(b'u') {
                bail!("lone high surrogate");
            }
            self.pos += 1;
            let low = self.hex4()?;
            if !(0xdc00..0xe000).contains(&low) {
                bail!("invalid low surrogate");
            }
            0x10000 + ((first - 0xd800) << 10) + (low - 0xdc00)
        } else {
            first
        };
        char::from_u32(code).ok_or_else(|| anyhow::anyhow!("invalid \\u escape"))
    }

    fn hex4(&mut self) -> Result<u32> {
        let end = self.pos + 4;
        if end > self.bytes.len() {
            bail!("truncated \\u escape");
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..end])
            .map_err(|_| anyhow::anyhow!("non-ASCII \\u escape"))?;
        let v = u32::from_str_radix(hex, 16)
            .map_err(|_| anyhow::anyhow!("bad \\u escape '{hex}'"))?;
        self.pos = end;
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" [1, 2.5, -3e2] ").unwrap().as_array().unwrap().len(), 3);
        let v = parse("{\"a\":1,\"b\":{\"c\":[]}}").unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_array(), Some(&[][..]));
        assert!(v.get("missing").is_none());
        assert_eq!(parse("{}").unwrap(), Value::Object(Vec::new()));
    }

    #[test]
    fn numbers_keep_exactness() {
        // u64 beyond f64's 2^53 integer range reads back exactly.
        let v = parse("18446744073709551615").unwrap();
        assert_eq!(v.as_u64(), Some(u64::MAX));
        // Shortest-repr floats round-trip bit-exact through Display.
        for x in [0.1f64, -2.5e-3, 1.0 / 3.0, f64::MIN_POSITIVE, 1e308] {
            let text = format!("{x}");
            let back = parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{text}");
        }
        assert!(parse("1.2.3").is_err());
        assert!(parse("--1").is_err());
    }

    #[test]
    fn null_reads_back_as_nan_for_metrics() {
        // metrics::json::number writes non-finite floats as null.
        assert!(parse("null").unwrap().as_f64_or_nan().unwrap().is_nan());
        assert_eq!(parse("2.5").unwrap().as_f64_or_nan(), Some(2.5));
        assert_eq!(Value::Str("x".into()).as_f64_or_nan(), None);
    }

    #[test]
    fn string_escapes_round_trip_with_the_emitter() {
        // Everything metrics::json::string can emit parses back to the
        // original text.
        for s in ["a\"b\\c\n", "\r\t", "\u{1}\u{1f}", "héllo", "π≈3"] {
            let emitted = crate::metrics::json::string(s);
            assert_eq!(parse(&emitted).unwrap().as_str(), Some(s), "{emitted}");
        }
        // Surrogate pairs decode (other emitters may write them).
        assert_eq!(parse("\"\\ud83d\\ude00\"").unwrap().as_str(), Some("\u{1f600}"));
        assert!(parse("\"\\ud83d\"").is_err(), "lone surrogate rejected");
    }

    #[test]
    fn object_key_order_is_preserved() {
        let v = parse("{\"z\":1,\"a\":2}").unwrap();
        let keys: Vec<&str> =
            v.as_object().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a"]);
    }

    #[test]
    fn torn_documents_are_rejected() {
        // A journal line cut mid-write must fail, not parse as a prefix.
        let full = "{\"v\":1,\"idx\":3,\"records\":[{\"ws\":1.25}]}";
        assert!(parse(full).is_ok());
        for cut in 1..full.len() {
            assert!(parse(&full[..cut]).is_err(), "cut at {cut} parsed");
        }
        assert!(parse("{} trailing").is_err());
        assert!(parse("").is_err());
    }
}
