//! Trace subsystem: a versioned compact binary format for recorded
//! `TraceOp` streams, with a streaming memory-bounded reader, a
//! writer, and JSONL conversion (DESIGN.md §Trace subsystem).
//!
//! A trace file captures the per-core op streams a workload feeds the
//! simulator, so any run can be recorded once and replayed exactly —
//! under either backend, any mechanism/placement/SALP configuration —
//! or shipped between machines as a compact artifact. Trace-backed
//! workloads are first-class: `trace:<path>` is a valid workload axis
//! value, and cache/journal keys fold in a digest of the file's
//! *content* (not its path), so editing a trace in place invalidates
//! cached results.

pub mod format;
pub mod jsonl;
pub mod reader;
pub mod writer;

use std::fs::File;
use std::io::Read;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::cpu::trace::Trace;
use crate::util::hash::StreamDigest;
use crate::workloads::generators::{CoreSpec, WorkloadKind};
use crate::workloads::Workload;

pub use reader::TraceReader;
pub use writer::write_trace;

/// A validated, content-addressed reference to a trace file, carried
/// by trace-backed `Workload`s.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceSource {
    pub path: PathBuf,
    /// Content digest of the whole file (folds into cache/journal
    /// keys so results are keyed by what the trace *is*, not where it
    /// lives).
    pub digest: String,
    /// Set by alone-run decomposition: load only this core's stream.
    pub only_core: Option<usize>,
}

impl TraceSource {
    /// Decode the per-core op streams (or just `only_core`'s). The
    /// file was validated at workload-build time, so errors here mean
    /// it changed underfoot.
    pub fn load_traces(&self) -> Result<Vec<Trace>> {
        let mut rd = TraceReader::open(&self.path)?;
        let cores = rd.header().streams.len();
        let picked: Vec<usize> = match self.only_core {
            Some(c) => {
                if c >= cores {
                    bail!(
                        "core {c} out of range ({} has {cores} streams)",
                        self.path.display()
                    );
                }
                vec![c]
            }
            None => (0..cores).collect(),
        };
        picked
            .into_iter()
            .map(|core| Ok(Trace::new(rd.ops(core)?.collect_ops()?)))
            .collect()
    }
}

/// Content digest of any file, streamed in bounded chunks.
/// `util::hash::StreamDigest` is chunking-invariant, so this equals a
/// single-shot digest of the whole file.
pub fn file_digest(path: &Path) -> Result<String> {
    let mut f = File::open(path)
        .with_context(|| format!("opening {} for digest", path.display()))?;
    let mut digest = StreamDigest::new();
    let mut buf = vec![0u8; 64 << 10];
    loop {
        let n = f
            .read(&mut buf)
            .with_context(|| format!("digesting {}", path.display()))?;
        if n == 0 {
            break;
        }
        digest.update(&buf[..n]);
    }
    Ok(digest.finish())
}

/// Build a trace-backed `Workload` from a file: validate the whole
/// file up front (header, every op of every stream, no empty
/// streams), then digest its content. Core specs are placeholders —
/// the recorded streams themselves carry all behaviour.
pub fn workload_from_file(path: &Path) -> Result<Workload> {
    let mut rd = TraceReader::open(path)?;
    let cores = rd.header().streams.len();
    let name = rd.header().name.clone();
    for core in 0..cores {
        if rd.header().streams[core].op_count == 0 {
            bail!(
                "{}: core {core} has an empty op stream (replay cycles over ops)",
                path.display()
            );
        }
        let mut it = rd.ops(core)?;
        let mut prev = 0u64;
        let mut n = 0u64;
        while let Some(op) = it.next_op(&mut prev) {
            op.with_context(|| format!("validating {}", path.display()))?;
            n += 1;
        }
        debug_assert_eq!(n, rd.header().streams[core].op_count);
    }
    let digest = file_digest(path)?;
    let placeholder =
        CoreSpec { kind: WorkloadKind::Random, wss: 0, nonmem: 0, write_frac: 0.0 };
    Ok(Workload {
        name,
        cores: vec![placeholder; cores],
        source: Some(TraceSource { path: path.to_path_buf(), digest, only_core: None }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::trace::{BulkOp, TraceOp};

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lisa-trace-mod-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample() -> Vec<Trace> {
        vec![
            Trace::new(vec![
                TraceOp::Mem { nonmem: 4, addr: 64, is_write: false, dependent: false },
                TraceOp::Bulk {
                    nonmem: 4,
                    op: BulkOp::Touch { va: 8192, is_write: true, dependent: true },
                },
            ]),
            Trace::new(vec![TraceOp::Copy { nonmem: 10, src: 0, dst: 8192, rows: 1 }]),
        ]
    }

    #[test]
    fn workload_from_file_validates_and_digests() {
        let p = tmp("wl.trc");
        write_trace(&p, "sample", &sample()).unwrap();
        let wl = workload_from_file(&p).unwrap();
        assert_eq!(wl.name, "sample");
        assert_eq!(wl.cores.len(), 2);
        let src = wl.source.as_ref().unwrap();
        // The chunked file digest must equal a single-shot digest of
        // the same bytes (StreamDigest is chunking-invariant).
        let mut oneshot = StreamDigest::new();
        oneshot.update(&std::fs::read(&p).unwrap());
        assert_eq!(src.digest, oneshot.finish());
        assert_eq!(src.digest.len(), 32);
        assert_eq!(src.only_core, None);
        // only_core narrows the load to one stream.
        let mut narrowed = src.clone();
        narrowed.only_core = Some(1);
        let traces = narrowed.load_traces().unwrap();
        assert_eq!(traces.len(), 1);
        assert_eq!(traces[0].ops, sample()[1].ops);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupt_files_never_build_workloads() {
        let p = tmp("bad.trc");
        write_trace(&p, "sample", &sample()).unwrap();
        let good = std::fs::read(&p).unwrap();

        // Truncated mid-stream.
        std::fs::write(&p, &good[..good.len() - 1]).unwrap();
        let err = format!("{:#}", workload_from_file(&p).unwrap_err());
        assert!(
            err.contains("past end of file") || err.contains("truncated"),
            "{err}"
        );

        // Truncated mid-header.
        std::fs::write(&p, &good[..10]).unwrap();
        let err = format!("{:#}", workload_from_file(&p).unwrap_err());
        assert!(err.contains("header"), "{err}");

        // Garbage op bytes inside a stream (flip a tag to an unknown
        // value). Stream 0 starts right after the header.
        let mut bad = good.clone();
        let stream0 = (format::TraceHeader::byte_len("sample", 2)) as usize;
        bad[stream0] = 0xee;
        std::fs::write(&p, &bad).unwrap();
        let err = format!("{:#}", workload_from_file(&p).unwrap_err());
        assert!(err.contains("unknown op tag"), "{err}");
        std::fs::remove_file(&p).ok();
    }
}
