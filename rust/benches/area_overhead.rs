//! Bench E8 (paper §2): LISA die-area overhead (paper: 0.8% in 28 nm)
//! with a sensitivity sweep over subarray count.

use lisa::config::DramConfig;
use lisa::dram::area::AreaModel;
use lisa::util::bench::Table;

fn main() {
    println!("=== E8: die-area overhead ===\n");
    let model = AreaModel::default();
    let mut t = Table::new(&["subarrays/bank", "iso %", "control %", "total %"]);
    for sas in [8usize, 16, 32, 64] {
        let mut cfg = DramConfig::default();
        cfg.subarrays_per_bank = sas;
        cfg.rows_per_subarray = 8192 / sas; // constant capacity
        let r = model.overhead(&cfg);
        t.row(&[
            format!("{sas}"),
            format!("{:.3}", r.iso_fraction * 100.0),
            format!("{:.3}", r.control_fraction * 100.0),
            format!("{:.3}", r.total_fraction * 100.0),
        ]);
    }
    t.print();
    println!("\npaper: 0.8% total at 16 subarrays/bank (row-buffer decoupling figures)");
}
