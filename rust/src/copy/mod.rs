//! Bulk-copy engines: every mechanism in Table 1 / Fig. 2 of the paper.
//!
//! * `memcpy` — baseline: lines cross the channel twice (RD burst into
//!   the CPU, WR burst back). Expanded by the controller into real
//!   RD/WR requests; modeled here only for isolated-latency studies.
//! * RowClone intra-subarray (`rc-intra`) — ACT, ACT_COPY, PRE.
//! * RowClone inter-bank (`rc-bank`) — pipelined serial mode over the
//!   internal 64-bit bus.
//! * RowClone inter-subarray (`rc-inter`) — two inter-bank legs via a
//!   temporary bank (the state of the art the paper improves on).
//! * LISA-RISC (`lisa-risc`) — ACT(src), RBM across hops, ACT_STORE,
//!   PRE; latency grows linearly with hop count (paper §3.1.1).
//!
//! `CopyOp` is the controller-side state machine that emits the
//! command sequence; `isolated_copy` drives a fresh device directly to
//! measure a mechanism's intrinsic latency/energy (Table 1 numbers).

use anyhow::Result;

use crate::config::{Calibration, CopyMechanism, DramConfig, LisaConfig, SalpMode};
use crate::controller::request::CopyRequest;
use crate::dram::bank::DramDevice;
use crate::dram::command::Command;
use crate::dram::geometry::Address;
use crate::dram::timing::{SpeedBin, Timing};

/// Reserved row used as the bounce buffer for RC-InterSA two-leg
/// copies (last row of the temp bank).
fn temp_row(cfg: &DramConfig) -> usize {
    cfg.rows_per_bank() - 1
}

/// The per-row command sequence progress for one in-DRAM copy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Ensure the involved banks are precharged.
    PreSrcBank,
    PreDstBank,
    ActSrc,
    // RC-intra
    ActCopyDst,
    // LISA-RISC
    Rbm,
    ActStoreDst,
    // Inter-bank legs
    ActTmp,
    TransferToTmp,
    PreSrcForLeg2,
    ActDstLeg2,
    TransferToDst,
    ActDstDirect,
    TransferDirect,
    // Closing
    PreFinal,
    PreFinalDst,
    Done,
}

/// State machine for one (possibly multi-row) in-DRAM copy request.
/// The controller asks for `next_command` whenever it can schedule,
/// and reports issues back via `on_issued`.
#[derive(Debug, Clone)]
pub struct CopyOp {
    pub req: CopyRequest,
    /// Effective mechanism for this src/dst pair (falls back when the
    /// requested mechanism cannot serve the pair's geometry).
    pub mechanism: CopyMechanism,
    row_idx: usize,
    phase: Phase,
    /// Completion cycle of the last issued step.
    pub last_done: u64,
    pub done: bool,
}

/// Pick the mechanism actually usable for a src/dst pair: LISA-RISC
/// only links subarrays within a bank; RowClone intra needs the same
/// subarray, etc. (The paper's controller does the same dispatch.)
pub fn effective_mechanism(
    req_mech: CopyMechanism,
    src: &Address,
    dst: &Address,
    cfg: &DramConfig,
) -> CopyMechanism {
    use CopyMechanism::*;
    if req_mech == MemcpyChannel {
        return MemcpyChannel;
    }
    if src.same_subarray(dst, cfg) {
        // Same subarray: every in-DRAM mechanism degenerates to
        // RowClone intra-subarray (it is also the fastest).
        return RowCloneIntraSa;
    }
    if src.same_bank(dst) {
        return match req_mech {
            LisaRisc => LisaRisc,
            RowCloneIntraSa | RowCloneInterSa => RowCloneInterSa,
            RowCloneInterBank => RowCloneInterSa,
            MemcpyChannel => unreachable!(),
        };
    }
    // Different banks: direct inter-bank transfer (one leg).
    RowCloneInterBank
}

impl CopyOp {
    pub fn new(req: CopyRequest, cfg: &DramConfig) -> Self {
        let mechanism = effective_mechanism(req.mechanism, &req.src, &req.dst, cfg);
        Self {
            req,
            mechanism,
            row_idx: 0,
            phase: Phase::PreSrcBank,
            last_done: 0,
            done: false,
        }
    }

    fn src(&self) -> Address {
        let mut a = self.req.src;
        a.row += self.row_idx;
        a
    }

    fn dst(&self) -> Address {
        let mut a = self.req.dst;
        a.row += self.row_idx;
        a
    }

    fn tmp_bank(&self, cfg: &DramConfig) -> usize {
        (self.src().bank + 1) % cfg.banks
    }

    /// Under MASA, same-bank mechanisms only need their hop path (for
    /// LISA-RISC) or single subarray (RowClone intra) precharged — open
    /// rows in other subarrays are preserved across the copy, which is
    /// the SALP × LISA composition payoff. Inter-bank mechanisms still
    /// close whole banks: `Transfer` grabs *the* open row of a bank, so
    /// exactly one may exist. Returns the inclusive subarray span to
    /// clear, or `None` when whole-bank precharge applies.
    fn selective_span(
        &self,
        dev: &DramDevice,
        src: &Address,
        dst: &Address,
    ) -> Option<(usize, usize)> {
        if dev.cfg.salp != SalpMode::Masa {
            return None;
        }
        match self.mechanism {
            CopyMechanism::LisaRisc | CopyMechanism::RowCloneIntraSa => {
                let a = src.subarray(&dev.cfg);
                let b = dst.subarray(&dev.cfg);
                Some((a.min(b), a.max(b)))
            }
            _ => None,
        }
    }

    /// The next command to issue, or None when this row's sequence is
    /// complete / the op is done. Pure function of current phase +
    /// device state (skips unnecessary precharges).
    pub fn next_command(&mut self, dev: &DramDevice) -> Option<Command> {
        use CopyMechanism::*;
        if self.done {
            return None;
        }
        let cfg = &dev.cfg;
        let mut src = self.src();
        let mut dst = self.dst();
        let (ch, rank) = (src.channel, src.rank);
        debug_assert!(self.mechanism != MemcpyChannel,
                      "memcpy is expanded by the controller");
        let _ = ch;
        loop {
            match self.phase {
                Phase::PreSrcBank => {
                    if let Some((lo, hi)) = self.selective_span(dev, &src, &dst) {
                        let b = dev.bank(ch, rank, src.bank);
                        for sa in lo..=hi {
                            if !b.subarrays[sa].is_precharged() {
                                return Some(Command::PreSa { rank, bank: src.bank, sa });
                            }
                        }
                    } else if !dev.bank(ch, rank, src.bank).all_precharged() {
                        return Some(Command::Pre { rank, bank: src.bank });
                    }
                    self.phase = Phase::PreDstBank;
                }
                Phase::PreDstBank => {
                    let needs = !src.same_bank(&dst)
                        || self.mechanism == RowCloneInterSa;
                    let dst_bank = if self.mechanism == RowCloneInterSa {
                        self.tmp_bank(cfg)
                    } else {
                        dst.bank
                    };
                    if needs && !dev.bank(ch, rank, dst_bank).all_precharged() {
                        return Some(Command::Pre { rank, bank: dst_bank });
                    }
                    self.phase = Phase::ActSrc;
                }
                Phase::ActSrc => {
                    self.phase = match self.mechanism {
                        RowCloneIntraSa => Phase::ActCopyDst,
                        LisaRisc => Phase::Rbm,
                        RowCloneInterSa => Phase::ActTmp,
                        RowCloneInterBank => Phase::ActDstDirect,
                        MemcpyChannel => unreachable!(),
                    };
                    return Some(Command::Act { rank, bank: src.bank, row: src.row });
                }
                Phase::ActCopyDst => {
                    self.phase = Phase::PreFinal;
                    return Some(Command::ActCopy { rank, bank: dst.bank, row: dst.row });
                }
                Phase::Rbm => {
                    self.phase = Phase::ActStoreDst;
                    return Some(Command::Rbm {
                        rank,
                        bank: src.bank,
                        from_sa: src.subarray(cfg),
                        to_sa: dst.subarray(cfg),
                    });
                }
                Phase::ActStoreDst => {
                    self.phase = Phase::PreFinal;
                    return Some(Command::ActStore { rank, bank: dst.bank, row: dst.row });
                }
                Phase::ActTmp => {
                    self.phase = Phase::TransferToTmp;
                    return Some(Command::Act {
                        rank,
                        bank: self.tmp_bank(cfg),
                        row: temp_row(cfg),
                    });
                }
                Phase::TransferToTmp => {
                    self.phase = Phase::PreSrcForLeg2;
                    return Some(Command::Transfer {
                        rank,
                        src_bank: src.bank,
                        dst_bank: self.tmp_bank(cfg),
                        cols: cfg.columns,
                    });
                }
                Phase::PreSrcForLeg2 => {
                    self.phase = Phase::ActDstLeg2;
                    return Some(Command::Pre { rank, bank: src.bank });
                }
                Phase::ActDstLeg2 => {
                    self.phase = Phase::TransferToDst;
                    return Some(Command::Act { rank, bank: dst.bank, row: dst.row });
                }
                Phase::TransferToDst => {
                    self.phase = Phase::PreFinal;
                    return Some(Command::Transfer {
                        rank,
                        src_bank: self.tmp_bank(cfg),
                        dst_bank: dst.bank,
                        cols: cfg.columns,
                    });
                }
                Phase::ActDstDirect => {
                    self.phase = Phase::TransferDirect;
                    return Some(Command::Act { rank, bank: dst.bank, row: dst.row });
                }
                Phase::TransferDirect => {
                    self.phase = Phase::PreFinal;
                    return Some(Command::Transfer {
                        rank,
                        src_bank: src.bank,
                        dst_bank: dst.bank,
                        cols: cfg.columns,
                    });
                }
                Phase::PreFinal => {
                    if let Some((lo, hi)) = self.selective_span(dev, &src, &dst) {
                        // Close only the hop path (source, destination
                        // and the latched intermediates), one subarray
                        // per scheduling slot; the phase repeats until
                        // the whole path is clean.
                        let b = dev.bank(ch, rank, src.bank);
                        for sa in lo..=hi {
                            if !b.subarrays[sa].is_precharged() {
                                return Some(Command::PreSa { rank, bank: src.bank, sa });
                            }
                        }
                        self.phase = Phase::PreFinalDst;
                    } else if !dev.bank(ch, rank, src.bank).all_precharged() {
                        self.phase = Phase::PreFinalDst;
                        return Some(Command::Pre { rank, bank: src.bank });
                    } else {
                        self.phase = Phase::PreFinalDst;
                    }
                }
                Phase::PreFinalDst => {
                    // Close whichever other banks the mechanism touched.
                    let mut banks = vec![];
                    if !src.same_bank(&dst) {
                        banks.push(dst.bank);
                    }
                    if self.mechanism == RowCloneInterSa {
                        banks.push(self.tmp_bank(cfg));
                    }
                    for b in banks {
                        if !dev.bank(ch, rank, b).all_precharged() {
                            return Some(Command::Pre { rank, bank: b });
                        }
                    }
                    self.phase = Phase::Done;
                }
                Phase::Done => {
                    self.row_idx += 1;
                    if self.row_idx >= self.req.rows {
                        self.done = true;
                        return None;
                    }
                    self.phase = Phase::PreSrcBank;
                    // Re-derive the per-row addresses for the next row.
                    src = self.src();
                    dst = self.dst();
                }
            }
        }
    }

    /// Record an issued step's completion time.
    pub fn on_issued(&mut self, done_at: u64) {
        self.last_done = self.last_done.max(done_at);
    }

    /// Every bank this copy's sequence touches (the controller keeps
    /// normal traffic from re-opening rows there while the copy runs;
    /// all OTHER banks keep serving requests — LISA's bank-level
    /// parallelism claim).
    pub fn banks(&self, cfg: &DramConfig) -> [Option<usize>; 3] {
        let src = self.req.src.bank;
        let dst = self.req.dst.bank;
        let mut out = [Some(src), None, None];
        if dst != src {
            out[1] = Some(dst);
        }
        if self.mechanism == CopyMechanism::RowCloneInterSa {
            out[2] = Some(self.tmp_bank(cfg));
        }
        out
    }

    /// Restart the current row's sequence from the beginning. Used by
    /// the controller when an external event (a refresh-forced
    /// precharge) invalidated the in-flight analog state (e.g. wiped
    /// the latched row buffers an ACT_STORE depended on). The sequence
    /// is idempotent per row, so re-running it is always safe.
    pub fn restart_row(&mut self) {
        if !self.done {
            self.phase = Phase::PreSrcBank;
        }
    }
}

/// Result of an isolated copy measurement.
#[derive(Debug, Clone)]
pub struct IsolatedCopy {
    pub mechanism: CopyMechanism,
    pub hops: usize,
    pub latency_ns: f64,
    /// Command counts incurred (for the energy model).
    pub stats: crate::dram::bank::CommandStats,
}

/// Drive a fresh device through one 8 KB row copy with no competing
/// traffic and report its intrinsic latency (the Table 1 experiment).
/// `hops` picks the subarray distance for inter-subarray mechanisms.
pub fn isolated_copy(
    mechanism: CopyMechanism,
    hops: usize,
    speed: SpeedBin,
    cal: &Calibration,
) -> Result<IsolatedCopy> {
    let cfg = DramConfig::default();
    let mut lisa = LisaConfig::default();
    lisa.risc = true;
    let timing = Timing::new(speed, cal);
    let mut dev = DramDevice::new(cfg.clone(), lisa, timing);

    let src = Address { channel: 0, rank: 0, bank: 0, row: 0, col: 0 };
    // hops == 0 means an intra-subarray copy (another row of the same
    // subarray); the inter-bank mechanism needs a cross-bank pair.
    let dst = if mechanism == CopyMechanism::RowCloneInterBank {
        Address { channel: 0, rank: 0, bank: 1, row: 0, col: 0 }
    } else {
        Address {
            channel: 0,
            rank: 0,
            bank: 0,
            row: if hops == 0 { 1 } else { hops * cfg.rows_per_subarray },
            col: 0,
        }
    };

    let latency_cycles = match mechanism {
        CopyMechanism::MemcpyChannel => isolated_memcpy(&mut dev, &src, &dst)?,
        _ => {
            let req = CopyRequest {
                id: 0,
                core: 0,
                src,
                dst,
                rows: 1,
                mechanism,
                arrive: 0,
            };
            let mut op = CopyOp::new(req, &cfg);
            let mut now = 0u64;
            let mut last_done = 0u64;
            while let Some(cmd) = op.next_command(&dev) {
                let at = dev.earliest(0, cmd, now)?;
                let issued = dev.issue(0, cmd, at)?;
                now = at + 1;
                last_done = last_done.max(issued.done_at);
                op.on_issued(issued.done_at);
            }
            last_done
        }
    };

    Ok(IsolatedCopy {
        mechanism,
        hops,
        latency_ns: dev.timing.ns(latency_cycles),
        stats: dev.stats.clone(),
    })
}

/// Isolated memcpy over the channel: ACT src, stream all 128 line
/// reads, ACT dst, stream all 128 writes (store buffer drains after
/// the read phase), PRE. Data crosses the pin-limited channel twice.
fn isolated_memcpy(dev: &mut DramDevice, src: &Address, dst: &Address) -> Result<u64> {
    let cols = dev.cfg.columns;
    let src_sa = src.subarray(&dev.cfg);
    let dst_sa = dst.subarray(&dev.cfg);
    let mut now = 0u64;

    let act = Command::Act { rank: src.rank, bank: src.bank, row: src.row };
    let at = dev.earliest(0, act, now)?;
    dev.issue(0, act, at)?;
    now = at + 1;

    let mut last_rd_done = 0;
    for col in 0..cols {
        let rd = Command::Rd { rank: src.rank, bank: src.bank, sa: src_sa, col };
        let at = dev.earliest(0, rd, now)?;
        let done = dev.issue(0, rd, at)?.done_at;
        last_rd_done = done;
        now = at + 1;
    }
    // Source can close while writes stream (different row).
    let pre = Command::Pre { rank: src.rank, bank: src.bank };
    let at = dev.earliest(0, pre, now)?;
    dev.issue(0, pre, at)?;

    // Destination row activation (same bank must wait for the PRE).
    let act2 = Command::Act { rank: dst.rank, bank: dst.bank, row: dst.row };
    let at = dev.earliest(0, act2, now)?;
    dev.issue(0, act2, at)?;
    now = at + 1;

    let mut last_done = last_rd_done;
    for col in 0..cols {
        let wr = Command::Wr { rank: dst.rank, bank: dst.bank, sa: dst_sa, col };
        let at = dev.earliest(0, wr, now)?;
        let done = dev.issue(0, wr, at)?.done_at;
        last_done = last_done.max(done);
        now = at + 1;
    }
    let tag = dev.row_tag(0, src.rank, src.bank, src.row);
    dev.set_row_tag(0, dst.rank, dst.bank, dst.row, tag);

    let pre2 = Command::Pre { rank: dst.rank, bank: dst.bank };
    let at = dev.earliest(0, pre2, now)?;
    let done = dev.issue(0, pre2, at)?.done_at;
    Ok(done.max(last_done))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Calibration;

    fn run(mech: CopyMechanism, hops: usize) -> IsolatedCopy {
        isolated_copy(mech, hops, SpeedBin::Ddr3_1600, &Calibration::default()).unwrap()
    }

    #[test]
    fn rc_intra_matches_paper_anchor() {
        // Table 1: RC-IntraSA = 83.75 ns (ACT + ACT + PRE).
        let r = run(CopyMechanism::RowCloneIntraSa, 0);
        assert!((r.latency_ns - 83.75).abs() < 2.0, "{}", r.latency_ns);
    }

    #[test]
    fn lisa_risc_linear_in_hops() {
        let r1 = run(CopyMechanism::LisaRisc, 1);
        let r7 = run(CopyMechanism::LisaRisc, 7);
        let r15 = run(CopyMechanism::LisaRisc, 15);
        assert!(r1.latency_ns < r7.latency_ns && r7.latency_ns < r15.latency_ns);
        // Slope ~ tRBM per hop (paper: ~8 ns).
        let slope = (r15.latency_ns - r1.latency_ns) / 14.0;
        assert!((slope - 8.75).abs() < 1.5, "slope {slope}");
        // Must beat the paper's reported 148.5 ns fixed cost.
        assert!(r1.latency_ns < 148.5, "1-hop {}", r1.latency_ns);
    }

    #[test]
    fn mechanism_ordering_matches_paper() {
        // Fig. 2: memcpy ~ RC-InterSA >> RC-Bank >> LISA (9x+) > RC-Intra.
        let memcpy = run(CopyMechanism::MemcpyChannel, 7);
        let inter = run(CopyMechanism::RowCloneInterSa, 7);
        let bank = run(CopyMechanism::RowCloneInterBank, 7);
        let lisa = run(CopyMechanism::LisaRisc, 7);
        let intra = run(CopyMechanism::RowCloneIntraSa, 0);
        assert!(memcpy.latency_ns > 1200.0, "memcpy {}", memcpy.latency_ns);
        assert!(inter.latency_ns > 1200.0, "rc-inter {}", inter.latency_ns);
        assert!(bank.latency_ns > 600.0 && bank.latency_ns < 800.0,
                "rc-bank {}", bank.latency_ns);
        assert!(lisa.latency_ns < bank.latency_ns / 3.0);
        assert!(intra.latency_ns < lisa.latency_ns);
        // LISA beats RC-InterSA by ~9x (paper's headline).
        let speedup = inter.latency_ns / lisa.latency_ns;
        assert!(speedup > 6.0, "speedup {speedup}");
    }

    #[test]
    fn copy_moves_data_tags() {
        // Verified per mechanism by driving the op directly.
        for (mech, hops) in [
            (CopyMechanism::RowCloneIntraSa, 0usize),
            (CopyMechanism::LisaRisc, 3),
            (CopyMechanism::RowCloneInterSa, 5),
        ] {
            let cfg = DramConfig::default();
            let mut lisa = LisaConfig::default();
            lisa.risc = true;
            let timing = Timing::new(SpeedBin::Ddr3_1600, &Calibration::default());
            let mut dev = DramDevice::new(cfg.clone(), lisa, timing);
            let src = Address { channel: 0, rank: 0, bank: 0, row: 7, col: 0 };
            let dst = Address {
                channel: 0,
                rank: 0,
                bank: 0,
                row: hops * cfg.rows_per_subarray + 9,
                col: 0,
            };
            dev.set_row_tag(0, 0, 0, src.row, 0xCAFE + hops as u64);
            let req = CopyRequest {
                id: 0, core: 0, src, dst, rows: 1, mechanism: mech, arrive: 0,
            };
            let mut op = CopyOp::new(req, &cfg);
            let mut now = 0;
            while let Some(cmd) = op.next_command(&dev) {
                let at = dev.earliest(0, cmd, now).unwrap();
                dev.issue(0, cmd, at).unwrap();
                now = at + 1;
            }
            assert_eq!(
                dev.row_tag(0, 0, 0, dst.row),
                0xCAFE + hops as u64,
                "{mech:?} failed to move data"
            );
        }
    }

    #[test]
    fn masa_copy_preserves_off_path_open_rows() {
        // The SALP x LISA composition: under MASA a LISA-RISC copy
        // precharges only its hop path (per-subarray PREs), so an open
        // row in an unrelated subarray of the same bank survives the
        // whole copy sequence.
        let mut cfg = DramConfig::default();
        cfg.salp = SalpMode::Masa;
        let mut lisa = LisaConfig::default();
        lisa.risc = true;
        let timing = Timing::new(SpeedBin::Ddr3_1600, &Calibration::default());
        let mut dev = DramDevice::new(cfg.clone(), lisa, timing);
        // Park an open row in subarray 12 (off the 0 -> 3 hop path).
        let park = Command::Act { rank: 0, bank: 0, row: 12 * 512 + 5 };
        let e = dev.earliest(0, park, 0).unwrap();
        dev.issue(0, park, e).unwrap();
        dev.set_row_tag(0, 0, 0, 7, 0x5A1B);
        let req = CopyRequest {
            id: 0,
            core: 0,
            src: Address { channel: 0, rank: 0, bank: 0, row: 7, col: 0 },
            dst: Address { channel: 0, rank: 0, bank: 0, row: 3 * 512 + 9, col: 0 },
            rows: 1,
            mechanism: CopyMechanism::LisaRisc,
            arrive: 0,
        };
        let mut op = CopyOp::new(req, &cfg);
        let mut now = e + 1;
        let mut n_pre_sa = 0;
        while let Some(cmd) = op.next_command(&dev) {
            assert!(
                !matches!(cmd, Command::Pre { .. }),
                "whole-bank PRE defeats the selective path: {cmd:?}"
            );
            if matches!(cmd, Command::PreSa { .. }) {
                n_pre_sa += 1;
            }
            let at = dev.earliest(0, cmd, now).unwrap();
            dev.issue(0, cmd, at).unwrap();
            now = at + 1;
        }
        assert_eq!(dev.row_tag(0, 0, 0, 3 * 512 + 9), 0x5A1B);
        // The parked row survived the copy.
        assert_eq!(dev.bank(0, 0, 0).subarrays[12].open_row(), Some(12 * 512 + 5));
        // Source, destination and the two latched intermediates were
        // each closed individually.
        assert_eq!(n_pre_sa, 4);
    }

    #[test]
    fn effective_mechanism_dispatch() {
        let cfg = DramConfig::default();
        let a = |row: usize, bank: usize| Address {
            channel: 0, rank: 0, bank, row, col: 0,
        };
        use CopyMechanism::*;
        // Same subarray: always degenerates to intra.
        assert_eq!(
            effective_mechanism(LisaRisc, &a(0, 0), &a(5, 0), &cfg),
            RowCloneIntraSa
        );
        // Same bank, different subarray.
        assert_eq!(
            effective_mechanism(LisaRisc, &a(0, 0), &a(600, 0), &cfg),
            LisaRisc
        );
        assert_eq!(
            effective_mechanism(RowCloneInterSa, &a(0, 0), &a(600, 0), &cfg),
            RowCloneInterSa
        );
        // Cross bank.
        assert_eq!(
            effective_mechanism(LisaRisc, &a(0, 0), &a(0, 3), &cfg),
            RowCloneInterBank
        );
        // memcpy never transforms.
        assert_eq!(
            effective_mechanism(MemcpyChannel, &a(0, 0), &a(600, 0), &cfg),
            MemcpyChannel
        );
    }

    #[test]
    fn multi_row_copy_repeats_sequence() {
        let cfg = DramConfig::default();
        let mut lisa = LisaConfig::default();
        lisa.risc = true;
        let timing = Timing::new(SpeedBin::Ddr3_1600, &Calibration::default());
        let mut dev = DramDevice::new(cfg.clone(), lisa, timing);
        for r in 0..4 {
            dev.set_row_tag(0, 0, 0, r, 0x1000 + r as u64);
        }
        let req = CopyRequest {
            id: 0,
            core: 0,
            src: Address { channel: 0, rank: 0, bank: 0, row: 0, col: 0 },
            dst: Address { channel: 0, rank: 0, bank: 0, row: 2 * 512, col: 0 },
            rows: 4,
            mechanism: CopyMechanism::LisaRisc,
            arrive: 0,
        };
        let mut op = CopyOp::new(req, &cfg);
        let mut now = 0;
        while let Some(cmd) = op.next_command(&dev) {
            let at = dev.earliest(0, cmd, now).unwrap();
            dev.issue(0, cmd, at).unwrap();
            now = at + 1;
        }
        for r in 0..4 {
            assert_eq!(dev.row_tag(0, 0, 0, 2 * 512 + r), 0x1000 + r as u64);
        }
    }
}
